//! Synthetic [`Executor`]: runs the full fleet control plane with no
//! PJRT artifacts.
//!
//! Each batch costs a simulated service time (`base_us` + per-row µs,
//! by default derived per stream from the analytic hardware simulator —
//! see `PipelineBuilder::start_fleet`), spent in a real `sleep` so
//! batching, deadlines, and shard parallelism behave as they would over
//! a blocking device, and returns a deterministic checksum per sample.
//! Used by `topkima serve-fleet`'s load generator and the CI fleet
//! tests.
//!
//! [`BehavioralExecutor`] is the opt-in (`serve-fleet --behavioral`)
//! variant that replaces the modeled sleep with *real* circuit-macro
//! work: every batch runs through the programmed crossbar's batched MAC
//! ([`Crossbar::mac_rows_into`]) and the converter's batched top-k
//! conversion, so fleet load exercises the §Perf hot paths end to end
//! while staying deterministic (ideal converter — no RNG draws — and
//! per-sample outputs independent of batch composition).

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Duration;

use anyhow::Result;

use crate::attention::{ChunkedAttention, GeneratedKeys};
use crate::crossbar::{Crossbar, Tech};
use crate::softmax::macros::{
    run_macro, run_macro_with, MacroParts, TopkimaSelect,
};
use crate::softmax::SoftmaxKind;
use crate::util::rng::Rng;

use super::request::InputData;
use super::router::StreamKey;
use super::server::Executor;

/// Deterministic stand-in for a device-backed executor.
#[derive(Clone, Debug)]
pub struct SyntheticExecutor {
    /// Fixed per-batch overhead, µs (dispatch + readout).
    base_us: f64,
    /// Per-stream service cost, µs per executed row (incl. padding).
    cost_us_per_row: HashMap<StreamKey, f64>,
    /// Cost for streams with no explicit entry.
    default_cost_us: f64,
}

impl SyntheticExecutor {
    pub fn new(base_us: f64, default_cost_us: f64) -> SyntheticExecutor {
        SyntheticExecutor {
            base_us,
            cost_us_per_row: HashMap::new(),
            default_cost_us,
        }
    }

    /// Set one stream's per-row service cost (µs).
    pub fn with_stream_cost(
        mut self,
        key: StreamKey,
        us_per_row: f64,
    ) -> SyntheticExecutor {
        self.cost_us_per_row.insert(key, us_per_row);
        self
    }

    /// The per-row cost this executor would charge a stream.
    pub fn cost_for(&self, key: &StreamKey) -> f64 {
        *self.cost_us_per_row.get(key).unwrap_or(&self.default_cost_us)
    }
}

impl Executor for SyntheticExecutor {
    fn execute(
        &mut self,
        stream: &StreamKey,
        inputs: &[Arc<InputData>],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let busy_us = self.base_us + self.cost_for(stream) * bucket as f64;
        if busy_us > 0.0 {
            std::thread::sleep(Duration::from_micros(busy_us as u64));
        }
        Ok(inputs
            .iter()
            .map(|input| {
                let sum: f64 = match &**input {
                    InputData::F32(v) => {
                        v.iter().map(|&x| x as f64).sum()
                    }
                    InputData::I32(v) => {
                        v.iter().map(|&x| x as f64).sum()
                    }
                };
                vec![sum as f32, stream.1 as f32]
            })
            .collect())
    }
}

/// Crossbar depth (rows of K^T) of the behavioral streams — one PWM
/// code per input feature.
const BEHAVIORAL_DEPTH: usize = 64;
/// Score columns per behavioral stream tile.
const BEHAVIORAL_COLS: usize = 64;

/// One stream's circuit substrate inside a [`BehavioralExecutor`]: a
/// deterministically programmed K^T tile plus the stream's top-k.
#[derive(Clone, Debug)]
pub struct BehavioralMacro {
    parts: MacroParts,
    k: usize,
    /// Accelerator design the stream's batches run through; the legacy
    /// top-k path when registered via [`BehavioralExecutor::with_stream`].
    kind: SoftmaxKind,
}

/// Deterministic per-stream salt: every shard (and every run) derives
/// the same substrate from the stream key alone.
fn stream_salt(key: &StreamKey) -> u64 {
    key.0
        .bytes()
        .fold(key.1 as u64, |h, b| h.wrapping_mul(31).wrapping_add(b as u64))
}

impl BehavioralMacro {
    /// Program the stream's tile from a fixed pseudo-pattern seeded by
    /// the stream key, so every shard (and every run) builds the same
    /// substrate.
    fn new(
        key: &StreamKey,
        k: usize,
        kind: SoftmaxKind,
    ) -> BehavioralMacro {
        let salt = stream_salt(key);
        let kt: Vec<Vec<i32>> = (0..BEHAVIORAL_DEPTH)
            .map(|r| {
                (0..BEHAVIORAL_COLS)
                    .map(|c| {
                        let x = salt
                            .wrapping_add(r as u64 * 13)
                            .wrapping_add(c as u64 * 7);
                        ((x % 15) as i32) - 7
                    })
                    .collect()
            })
            .collect();
        let parts = MacroParts::new(Crossbar::program(
            Tech::Sram,
            256,
            256,
            BEHAVIORAL_DEPTH,
            &kt,
        ));
        BehavioralMacro { parts, k: k.min(BEHAVIORAL_COLS), kind }
    }
}

/// Embed one request sample into a depth-`d` Q row of PWM codes (±15,
/// the 5-bit input range) — deterministic in the sample alone.
fn embed_codes(d: usize, input: &InputData) -> Vec<i32> {
    let code = |i: usize, v: i64| -> i32 {
        ((v.wrapping_add(i as i64 * 7)).rem_euclid(31) - 15) as i32
    };
    match input {
        InputData::I32(v) if v.is_empty() => vec![0; d],
        InputData::F32(v) if v.is_empty() => vec![0; d],
        InputData::I32(v) => (0..d)
            .map(|i| {
                let s = v.get(i % v.len()).copied().unwrap_or(0);
                code(i, s as i64)
            })
            .collect(),
        InputData::F32(v) => (0..d)
            .map(|i| {
                let s = v.get(i % v.len()).copied().unwrap_or(0.0);
                code(i, (s * 16.0) as i64)
            })
            .collect(),
    }
}

/// Sparse probability checksum of one selection row, weighted by
/// (column + 1) — the long-stream analogue of the dense checksum the
/// tile streams emit, computed without materializing a seq-wide row
/// (same softmax math as `DigitalSoftmax::compute_sparse`: shared max,
/// exp-sum in selection order, ascending-column accumulation).
fn sel_checksum(sel: &[(usize, f64)]) -> f64 {
    if sel.is_empty() {
        return 0.0;
    }
    let m = sel.iter().map(|&(_, v)| v).fold(f64::NEG_INFINITY, f64::max);
    let mut sum = 0.0;
    for &(_, v) in sel {
        sum += (v - m).exp();
    }
    let mut sorted: Vec<(usize, f64)> = sel.to_vec();
    sorted.sort_unstable_by_key(|&(c, _)| c);
    sorted
        .iter()
        .map(|&(c, v)| (v - m).exp() / sum * (c + 1) as f64)
        .sum()
}

/// One long-document stream's substrate: a streaming chunked attention
/// engine over procedurally generated keys — the sequence is never
/// materialized, so a 16k–1M-column stream costs O(chunk) memory per
/// batch no matter the length.
#[derive(Clone, Debug)]
pub struct LongMacro {
    engine: ChunkedAttention<GeneratedKeys>,
    k: usize,
}

/// Deterministic memory figures of a long-context stream (reported in
/// `BENCH_fleet.json`).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct LongContextStats {
    pub seq_len: usize,
    /// Effective chunk width after the engine's physical clamp.
    pub chunk_cols: usize,
    pub peak_scratch_bytes: usize,
}

impl LongMacro {
    fn new(
        key: &StreamKey,
        k: usize,
        seq_len: usize,
        chunk_cols: usize,
    ) -> Result<LongMacro> {
        let keys = GeneratedKeys::new(
            stream_salt(key),
            seq_len,
            BEHAVIORAL_DEPTH,
        );
        let engine = ChunkedAttention::with_defaults(keys, chunk_cols)
            .map_err(|e| anyhow::anyhow!("long stream {}: {e}", key.0))?;
        Ok(LongMacro { engine, k: k.min(seq_len) })
    }

    /// One single-row probe run: the stream's deterministic peak-scratch
    /// figure (ideal converter, so the probe is byte-stable).
    fn stats(&self) -> Result<LongContextStats> {
        let q = vec![vec![0i32; self.engine.depth()]];
        let run = self
            .engine
            .run_streaming(&TopkimaSelect { k: self.k }, &q, &mut Rng::new(0))
            .map_err(|e| anyhow::anyhow!("long stream probe: {e}"))?;
        Ok(LongContextStats {
            seq_len: self.engine.seq_len(),
            chunk_cols: self.engine.chunk_cols(),
            peak_scratch_bytes: run.peak_scratch_bytes,
        })
    }
}

/// A behavioral stream's substrate: one monolithic tile (the classic
/// family) or a streaming long-context engine.
#[derive(Clone, Debug)]
enum StreamMacro {
    Tile(BehavioralMacro),
    Long(LongMacro),
}

/// Device stand-in that does real circuit-macro work per batch instead
/// of sleeping a modeled service time (`serve-fleet --behavioral`).
///
/// Batches are padded to the bucket with zero rows (padding costs real
/// MAC/conversion work, like a device), and each sample's output is a
/// checksum of its attention-probability row plus the stream's k — so
/// replayed traces can be compared across SIMD modes byte for byte.
#[derive(Clone, Debug)]
pub struct BehavioralExecutor {
    streams: HashMap<StreamKey, StreamMacro>,
}

impl BehavioralExecutor {
    pub fn new() -> BehavioralExecutor {
        BehavioralExecutor { streams: HashMap::new() }
    }

    /// Register a stream's substrate (programmed deterministically from
    /// the key). Runs the legacy top-k selection path.
    pub fn with_stream(mut self, key: StreamKey, k: usize) -> BehavioralExecutor {
        let m = BehavioralMacro::new(&key, k, SoftmaxKind::Topkima);
        self.streams.insert(key, StreamMacro::Tile(m));
        self
    }

    /// Register a stream running a specific registry design — the
    /// `serve-fleet --ab` path, where design B is a dense rival and the
    /// batch runs that design's selection strategy and cost schedule.
    pub fn with_stream_design(
        mut self,
        key: StreamKey,
        k: usize,
        kind: SoftmaxKind,
    ) -> BehavioralExecutor {
        let m = BehavioralMacro::new(&key, k, kind);
        self.streams.insert(key, StreamMacro::Tile(m));
        self
    }

    /// Register a long-document stream: `seq_len` key columns streamed
    /// `chunk_cols` at a time through the chunked attention engine.
    /// Errors when the geometry is out of contract (typed, not a panic —
    /// the dimensions come from CLI flags).
    pub fn with_long_stream(
        mut self,
        key: StreamKey,
        k: usize,
        seq_len: usize,
        chunk_cols: usize,
    ) -> Result<BehavioralExecutor> {
        let m = LongMacro::new(&key, k, seq_len, chunk_cols)?;
        self.streams.insert(key, StreamMacro::Long(m));
        Ok(self)
    }

    /// Deterministic memory stats of every long-context stream, sorted
    /// by stream key (HashMap order must never reach a BENCH file).
    pub fn long_context_stats(
        &self,
    ) -> Result<Vec<(StreamKey, LongContextStats)>> {
        let mut out = Vec::new();
        for (key, m) in &self.streams {
            if let StreamMacro::Long(lm) = m {
                out.push((key.clone(), lm.stats()?));
            }
        }
        out.sort_by(|a, b| {
            (a.0 .0.as_ref(), a.0 .1).cmp(&(b.0 .0.as_ref(), b.0 .1))
        });
        Ok(out)
    }
}

impl Default for BehavioralExecutor {
    fn default() -> BehavioralExecutor {
        BehavioralExecutor::new()
    }
}

impl Executor for BehavioralExecutor {
    fn execute(
        &mut self,
        stream: &StreamKey,
        inputs: &[Arc<InputData>],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let m = self
            .streams
            .get(stream)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "behavioral executor has no stream {}/k={}",
                    stream.0,
                    stream.1
                )
            })?;
        match m {
            StreamMacro::Tile(m) => {
                let d = m.parts.crossbar.depth();
                let rows = bucket.max(inputs.len());
                let mut q_rows: Vec<Vec<i32>> = Vec::with_capacity(rows);
                q_rows
                    .extend(inputs.iter().map(|i| embed_codes(d, i)));
                q_rows.resize(rows, vec![0; d]);
                // Ideal converter → the RNG is never drawn from; a
                // fresh one per batch keeps that explicit. The legacy
                // top-k streams keep their exact pre-registry call so
                // replayed traces stay byte-identical.
                let (probs, _cost) = if m.kind == SoftmaxKind::Topkima {
                    run_macro(
                        &m.parts,
                        &TopkimaSelect { k: m.k },
                        &q_rows,
                        &mut Rng::new(0),
                    )
                } else {
                    let model =
                        crate::softmax::registry::model_for(m.kind);
                    run_macro_with(
                        &m.parts,
                        model.strategy(m.k).as_ref(),
                        &model.schedule(),
                        &q_rows,
                        &mut Rng::new(0),
                    )
                };
                Ok(probs
                    .iter()
                    .take(inputs.len())
                    .map(|row| {
                        let sum: f64 = row
                            .iter()
                            .enumerate()
                            .map(|(c, &p)| (c + 1) as f64 * p)
                            .sum();
                        vec![sum as f32, stream.1 as f32]
                    })
                    .collect())
            }
            StreamMacro::Long(lm) => {
                let d = lm.engine.depth();
                let rows = bucket.max(inputs.len());
                let mut q_rows: Vec<Vec<i32>> = Vec::with_capacity(rows);
                q_rows
                    .extend(inputs.iter().map(|i| embed_codes(d, i)));
                q_rows.resize(rows, vec![0; d]);
                let run = lm
                    .engine
                    .run_streaming(
                        &TopkimaSelect { k: lm.k },
                        &q_rows,
                        &mut Rng::new(0),
                    )
                    .map_err(|e| {
                        anyhow::anyhow!("long stream {}: {e}", stream.0)
                    })?;
                Ok((0..inputs.len())
                    .map(|r| {
                        vec![
                            sel_checksum(run.sels.row(r)) as f32,
                            stream.1 as f32,
                        ]
                    })
                    .collect())
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn checksums_are_deterministic_and_cost_is_per_stream(
    ) {
        let key: StreamKey = (Arc::from("bert"), 5);
        let other: StreamKey = (Arc::from("vit"), 3);
        let mut e = SyntheticExecutor::new(0.0, 7.0)
            .with_stream_cost(key.clone(), 11.0);
        assert_eq!(e.cost_for(&key), 11.0);
        assert_eq!(e.cost_for(&other), 7.0);
        let inputs = vec![
            Arc::new(InputData::I32(vec![1, 2, 3])),
            Arc::new(InputData::F32(vec![0.5, 0.25])),
        ];
        let out = e.execute(&key, &inputs, 4).unwrap();
        assert_eq!(out, vec![vec![6.0, 5.0], vec![0.75, 5.0]]);
        let again = e.execute(&key, &inputs, 4).unwrap();
        assert_eq!(out, again);
    }

    #[test]
    fn behavioral_outputs_are_deterministic_and_batch_independent() {
        let key: StreamKey = (Arc::from("bert"), 5);
        let mut e = BehavioralExecutor::new().with_stream(key.clone(), 5);
        let a = Arc::new(InputData::I32(vec![3, -2, 9]));
        let b = Arc::new(InputData::F32(vec![0.25, -1.5]));
        let batch =
            e.execute(&key, &[a.clone(), b.clone()], 4).unwrap();
        assert_eq!(batch.len(), 2);
        for row in &batch {
            assert_eq!(row[1], 5.0);
            assert!(row[0].is_finite());
        }
        // re-running the same batch is byte-identical
        assert_eq!(batch, e.execute(&key, &[a.clone(), b.clone()], 4).unwrap());
        // per-sample outputs do not depend on batch composition or
        // padding bucket (ideal converter, row-independent macro)
        let solo_a = e.execute(&key, &[a.clone()], 1).unwrap();
        let solo_b = e.execute(&key, &[b.clone()], 8).unwrap();
        assert_eq!(batch[0], solo_a[0]);
        assert_eq!(batch[1], solo_b[0]);
        // unknown stream is a loud error, not a panic
        let other: StreamKey = (Arc::from("vit"), 3);
        assert!(e.execute(&other, &[a], 1).is_err());
    }

    #[test]
    fn rival_design_streams_serve_dense_batches() {
        // An A/B pair: topkima at k=5 vs a dense rival at k=0.
        let a_key: StreamKey = (Arc::from("bert"), 5);
        let b_key: StreamKey = (Arc::from("bert"), 0);
        let mut e = BehavioralExecutor::new()
            .with_stream(a_key.clone(), 5)
            .with_stream_design(b_key.clone(), 0, SoftmaxKind::Sole);
        let x = Arc::new(InputData::I32(vec![3, -2, 9]));
        let a = e.execute(&a_key, &[x.clone()], 2).unwrap();
        let b = e.execute(&b_key, &[x.clone()], 2).unwrap();
        assert_eq!(a[0][1], 5.0);
        assert_eq!(b[0][1], 0.0);
        assert!(b[0][0].is_finite() && b[0][0] > 0.0);
        // the two designs produce distinct checksums
        assert_ne!(a[0][0], b[0][0]);
        // deterministic across replays
        assert_eq!(b, e.execute(&b_key, &[x], 2).unwrap());
    }

    #[test]
    fn long_stream_serves_and_reports_bounded_scratch() {
        let key: StreamKey = (Arc::from("bert"), 8);
        let mut e = BehavioralExecutor::new()
            .with_long_stream(key.clone(), 8, 2048, 64)
            .unwrap();
        let a = Arc::new(InputData::I32(vec![3, -2, 9]));
        let out = e.execute(&key, &[a.clone()], 2).unwrap();
        assert_eq!(out.len(), 1);
        assert!(out[0][0] > 0.0, "prob-row checksum is positive");
        assert_eq!(out[0][1], 8.0);
        // deterministic and independent of the padding bucket
        let again = e.execute(&key, &[a.clone()], 4).unwrap();
        assert_eq!(out[0], again[0]);
        let stats = e.long_context_stats().unwrap();
        assert_eq!(stats.len(), 1);
        assert_eq!(stats[0].1.seq_len, 2048);
        assert_eq!(stats[0].1.chunk_cols, 64);
        assert!(stats[0].1.peak_scratch_bytes > 0);
        // 4× the sequence at the same chunk: peak scratch must not
        // scale with seq (the long-context guarantee)
        let e2 = BehavioralExecutor::new()
            .with_long_stream((Arc::from("bert"), 8), 8, 8192, 64)
            .unwrap();
        let s2 = e2.long_context_stats().unwrap();
        assert!(
            s2[0].1.peak_scratch_bytes
                <= stats[0].1.peak_scratch_bytes.saturating_mul(2),
            "peak grew with seq: {} -> {}",
            stats[0].1.peak_scratch_bytes,
            s2[0].1.peak_scratch_bytes
        );
        // bad geometry is a typed error, not a panic
        assert!(BehavioralExecutor::new()
            .with_long_stream((Arc::from("x"), 1), 1, 0, 64)
            .is_err());
    }
}
