//! Serving metrics: latency distribution, throughput, batch occupancy.

use std::time::{Duration, Instant};

use crate::util::json::Json;
use crate::util::stats;

/// Lock-free-enough metrics (single writer — the coordinator thread).
///
/// The throughput window is **event-frozen**: it spans the first to the
/// last recorded batch/error, not construction-to-call-time. The old
/// design stamped `started` at shard spawn and measured `elapsed()`
/// when `summary()` ran, so the reported rate depended on *when* the
/// summary was printed, kept decaying after `Fleet::shutdown`, and was
/// skewed low for streams that saw their first request late.
#[derive(Debug, Default)]
pub struct Metrics {
    /// First recorded event (batch completion or error); `None` until
    /// any traffic lands.
    first_event: Option<Instant>,
    /// Last recorded event — finalized implicitly: once traffic stops
    /// the window stops growing, whatever time `summary()` runs.
    last_event: Option<Instant>,
    latencies_us: Vec<f64>,
    batch_sizes: Vec<usize>,
    padded_rows: u64,
    errors: u64,
}

impl Metrics {
    fn touch(&mut self) {
        let now = Instant::now();
        self.first_event.get_or_insert(now);
        self.last_event = Some(self.last_event.map_or(now, |t| t.max(now)));
    }

    /// Record one completed batch.
    pub fn record_batch(
        &mut self,
        latencies_us: &[f64],
        bucket: usize,
        padding: usize,
    ) {
        self.touch();
        self.latencies_us.extend_from_slice(latencies_us);
        self.batch_sizes.push(bucket);
        self.padded_rows += padding as u64;
    }

    pub fn record_error(&mut self) {
        self.touch();
        self.errors += 1;
    }

    /// Count `n` errors at once (fleet-front rejections folded into an
    /// aggregate). Deliberately does NOT stamp the event window: this
    /// runs at aggregation time, not event time, and must never re-open
    /// a frozen window (`record_error` is the event-time path).
    pub fn add_errors(&mut self, n: u64) {
        self.errors += n;
    }

    /// Fold another metrics record into this one (fleet aggregation:
    /// per-stream → per-shard → fleet). The merged window is the union
    /// of both frozen windows (earliest first event → latest last
    /// event), so merging never re-opens a window against wall time.
    pub fn merge_from(&mut self, other: &Metrics) {
        self.first_event = match (self.first_event, other.first_event) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, b) => a.or(b),
        };
        self.last_event = match (self.last_event, other.last_event) {
            (Some(a), Some(b)) => Some(a.max(b)),
            (a, b) => a.or(b),
        };
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.padded_rows += other.padded_rows;
        self.errors += other.errors;
    }

    /// The frozen first-to-last-event window (zero with < 2 events).
    pub fn window(&self) -> Duration {
        match (self.first_event, self.last_event) {
            (Some(a), Some(b)) => b.saturating_duration_since(a),
            _ => Duration::ZERO,
        }
    }

    /// Executed padding rows (fleet padding-waste accounting).
    pub fn padded_rows(&self) -> u64 {
        self.padded_rows
    }

    /// Executed batches.
    pub fn batches(&self) -> usize {
        self.batch_sizes.len()
    }

    pub fn completed(&self) -> usize {
        self.latencies_us.len()
    }

    pub fn errors(&self) -> u64 {
        self.errors
    }

    /// Requests per second over the frozen event window. Stable no
    /// matter when it is read: a `summary()` printed a minute after
    /// shutdown reports the same rate as one printed immediately.
    /// Zero until the window has nonzero width (fewer than two distinct
    /// event instants cannot define a rate).
    pub fn throughput_rps(&self) -> f64 {
        let window = self.window().as_secs_f64();
        if window == 0.0 {
            return 0.0;
        }
        self.completed() as f64 / window
    }

    pub fn latency_percentile_us(&self, p: f64) -> f64 {
        stats::percentile(&self.latencies_us, p)
    }

    pub fn mean_latency_us(&self) -> f64 {
        stats::mean(&self.latencies_us)
    }

    /// Mean executed batch size (bucket, incl. padding).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64
            / self.batch_sizes.len() as f64
    }

    /// Fraction of executed rows that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let total: u64 =
            self.batch_sizes.iter().map(|&b| b as u64).sum();
        if total == 0 {
            return 0.0;
        }
        self.padded_rows as f64 / total as f64
    }

    /// Wire form for the shard-transport `MetricsSnapshot` frame: the
    /// raw samples (latencies, batch sizes) plus counters, with the
    /// frozen event window flattened to two relative measurements —
    /// its width (`window_us`) and how long ago it closed (`idle_us`,
    /// serialization time minus last event). `Instant`s cannot cross a
    /// process boundary, so [`Metrics::from_json`] re-anchors at parse
    /// time as `last = now - idle_us`, `first = last - window_us`:
    /// counts, percentiles, batch statistics, and the window width
    /// (hence per-record throughput) are preserved exactly, and
    /// *relative* window positions survive too — merging snapshots
    /// parsed at the same instant reproduces the true union window to
    /// within the serialize→parse latency skew, instead of collapsing
    /// disjoint windows onto one anchor (which would overstate merged
    /// throughput).
    pub fn to_json(&self) -> Json {
        let idle_us = self
            .last_event
            .map_or(0.0, |t| t.elapsed().as_secs_f64() * 1e6);
        Json::obj(vec![
            (
                "latencies_us",
                Json::Arr(
                    self.latencies_us.iter().map(|&v| Json::Num(v)).collect(),
                ),
            ),
            (
                "batch_sizes",
                Json::Arr(
                    self.batch_sizes
                        .iter()
                        .map(|&b| Json::Num(b as f64))
                        .collect(),
                ),
            ),
            ("padded_rows", Json::Num(self.padded_rows as f64)),
            ("errors", Json::Num(self.errors as f64)),
            (
                "window_us",
                Json::Num(self.window().as_secs_f64() * 1e6),
            ),
            ("idle_us", Json::Num(idle_us)),
        ])
    }

    /// Parse the wire form; unknown fields are rejected. See
    /// [`Metrics::to_json`] for the window re-anchoring caveat.
    pub fn from_json(v: &Json) -> Result<Metrics, String> {
        let obj = v.as_obj().ok_or("metrics must be an object")?;
        let mut m = Metrics::default();
        let mut window_us = 0.0f64;
        let mut idle_us = 0.0f64;
        let int = |x: &Json, field: &str| -> Result<u64, String> {
            x.as_u64().ok_or_else(|| {
                format!("{field} must be a non-negative integer")
            })
        };
        let micros = |x: &Json, field: &str| -> Result<f64, String> {
            x.as_f64()
                .filter(|n| *n >= 0.0 && n.is_finite())
                .ok_or_else(|| format!("{field} must be a non-negative number"))
        };
        for (key, value) in obj {
            match key.as_str() {
                "latencies_us" => {
                    m.latencies_us = value
                        .as_arr()
                        .ok_or("latencies_us must be an array")?
                        .iter()
                        .map(|x| {
                            // as strict as every other field: a NaN or
                            // negative sample would silently poison
                            // merged fleet percentiles
                            x.as_f64()
                                .filter(|n| n.is_finite() && *n >= 0.0)
                                .ok_or_else(|| {
                                    "latencies_us must be non-negative \
                                     finite numbers"
                                        .to_string()
                                })
                        })
                        .collect::<Result<_, _>>()?;
                }
                "batch_sizes" => {
                    m.batch_sizes = value
                        .as_arr()
                        .ok_or("batch_sizes must be an array")?
                        .iter()
                        .map(|x| int(x, "batch_sizes[]").map(|n| n as usize))
                        .collect::<Result<_, _>>()?;
                }
                "padded_rows" => {
                    m.padded_rows = int(value, "padded_rows")?
                }
                "errors" => m.errors = int(value, "errors")?,
                "window_us" => window_us = micros(value, "window_us")?,
                "idle_us" => idle_us = micros(value, "idle_us")?,
                other => {
                    return Err(format!("unknown metrics field '{other}'"))
                }
            }
        }
        if !m.latencies_us.is_empty()
            || !m.batch_sizes.is_empty()
            || m.errors > 0
        {
            let now = Instant::now();
            let last = now
                .checked_sub(Duration::from_secs_f64(idle_us * 1e-6))
                .unwrap_or(now);
            m.last_event = Some(last);
            m.first_event = Some(
                last.checked_sub(Duration::from_secs_f64(window_us * 1e-6))
                    .unwrap_or(last),
            );
        }
        Ok(m)
    }

    /// Multi-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "requests: {} ({} errors)\n\
             throughput: {:.1} req/s\n\
             latency µs: mean {:.0}, p50 {:.0}, p95 {:.0}, p99 {:.0}\n\
             mean batch {:.2}, padding {:.1}%",
            self.completed(),
            self.errors,
            self.throughput_rps(),
            self.mean_latency_us(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
            self.mean_batch_size(),
            100.0 * self.padding_fraction(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::default();
        m.record_batch(&[100.0, 200.0, 300.0, 400.0], 4, 0);
        m.record_batch(&[500.0], 2, 1);
        assert_eq!(m.completed(), 5);
        assert_eq!(m.mean_latency_us(), 300.0);
        assert_eq!(m.mean_batch_size(), 3.0);
        assert!((m.padding_fraction() - 1.0 / 6.0).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("requests: 5"));
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        let lats: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        m.record_batch(&lats, 100, 0);
        assert!(m.latency_percentile_us(50.0)
            <= m.latency_percentile_us(99.0));
    }

    #[test]
    fn merge_concatenates_and_sums() {
        let mut a = Metrics::default();
        a.record_batch(&[100.0, 200.0], 4, 2);
        a.record_error();
        let mut b = Metrics::default();
        b.record_batch(&[300.0], 2, 1);
        let mut all = Metrics::default();
        all.merge_from(&a);
        all.merge_from(&b);
        all.add_errors(2);
        assert_eq!(all.completed(), 3);
        assert_eq!(all.errors(), 3);
        assert_eq!(all.batches(), 2);
        assert_eq!(all.padded_rows(), 3);
        assert_eq!(all.mean_latency_us(), 200.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.completed(), 0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.padding_fraction(), 0.0);
        assert_eq!(m.throughput_rps(), 0.0);
        assert_eq!(m.window(), std::time::Duration::ZERO);
    }

    #[test]
    fn throughput_window_freezes_at_last_event() {
        // regression: the old `started.elapsed()` made throughput a
        // function of *when the summary was printed* — it kept decaying
        // after the last request completed
        let mut m = Metrics::default();
        m.record_batch(&[100.0], 1, 0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.record_batch(&[100.0, 100.0], 2, 0);
        let first = m.throughput_rps();
        assert!(first > 0.0, "two spaced events define a rate");
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert_eq!(
            m.throughput_rps(),
            first,
            "window must not keep growing after the last event"
        );
    }

    #[test]
    fn throughput_window_starts_at_first_event_not_construction() {
        // regression: per-stream Metrics::default() used to stamp the
        // start at shard spawn, skewing every stream that saw its first
        // request late
        let m = Metrics::default();
        std::thread::sleep(std::time::Duration::from_millis(10));
        let mut m = m;
        m.record_batch(&[50.0], 1, 0);
        // a single event instant has zero width: no rate yet, instead
        // of a tiny rate over the idle spawn-to-traffic gap
        assert!(m.window() < std::time::Duration::from_millis(10));
        assert_eq!(m.throughput_rps(), 0.0);
    }

    #[test]
    fn json_roundtrip_preserves_samples_counters_and_window_width() {
        let mut m = Metrics::default();
        m.record_batch(&[100.5, 200.25, 300.0], 4, 1);
        std::thread::sleep(std::time::Duration::from_millis(5));
        m.record_batch(&[42.0], 2, 1);
        m.record_error();
        let back = Metrics::from_json(&m.to_json()).unwrap();
        assert_eq!(back.completed(), m.completed());
        assert_eq!(back.batches(), m.batches());
        assert_eq!(back.errors(), m.errors());
        assert_eq!(back.padded_rows(), m.padded_rows());
        assert_eq!(back.mean_latency_us(), m.mean_latency_us());
        assert_eq!(back.mean_batch_size(), m.mean_batch_size());
        assert_eq!(back.padding_fraction(), m.padding_fraction());
        assert_eq!(
            back.latency_percentile_us(99.0),
            m.latency_percentile_us(99.0)
        );
        // window width (and hence throughput) survives, ±1 µs of
        // float-duration conversion
        let (a, b) = (m.window().as_secs_f64(), back.window().as_secs_f64());
        assert!((a - b).abs() < 2e-6, "window drifted: {a} vs {b}");
        // an empty metrics record stays windowless
        let empty = Metrics::from_json(&Metrics::default().to_json()).unwrap();
        assert_eq!(empty.window(), std::time::Duration::ZERO);
        assert_eq!(empty.completed(), 0);
    }

    #[test]
    fn parsed_windows_keep_relative_positions_when_merged() {
        // regression: re-anchoring every parsed window at "ends now"
        // collapsed disjoint per-shard windows onto one instant, so the
        // merged union shrank to max(width) and merged throughput was
        // overstated. idle_us preserves each window's distance from its
        // serialization instant, so the union survives the wire.
        let mut early = Metrics::default();
        early.record_batch(&[10.0], 1, 0);
        std::thread::sleep(std::time::Duration::from_millis(30));
        let mut late = Metrics::default();
        late.record_batch(&[10.0], 1, 0);
        // snapshot both at the same instant (what workers do at
        // shutdown): early's idle_us is ~30 ms, late's ~0
        let early_json = early.to_json();
        let late_json = late.to_json();
        // parse both at (nearly) the same instant, as the fleet front
        // does with its workers' snapshots
        let a = Metrics::from_json(&early_json).unwrap();
        let b = Metrics::from_json(&late_json).unwrap();
        let mut merged = Metrics::default();
        merged.merge_from(&a);
        merged.merge_from(&b);
        // both windows are zero-width, but ~30 ms apart: the union must
        // reflect the gap, not collapse to zero
        assert!(
            merged.window() >= std::time::Duration::from_millis(25),
            "union window collapsed: {:?}",
            merged.window()
        );
        assert!(merged.throughput_rps() > 0.0);
        assert!(
            merged.throughput_rps() < 1000.0,
            "rate over a collapsed window would explode: {}",
            merged.throughput_rps()
        );
    }

    #[test]
    fn json_violations_are_loud() {
        use crate::util::json::Json;
        let bad = Json::parse(r#"{"errors":-1}"#).unwrap();
        assert!(Metrics::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"qos":1}"#).unwrap();
        assert!(Metrics::from_json(&bad).unwrap_err().contains("qos"));
        let bad = Json::parse(r#"{"batch_sizes":[1.5]}"#).unwrap();
        assert!(Metrics::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"idle_us":-4}"#).unwrap();
        assert!(Metrics::from_json(&bad).is_err());
        let bad = Json::parse(r#"{"latencies_us":[-1e300]}"#).unwrap();
        assert!(Metrics::from_json(&bad).is_err());
        assert!(Metrics::from_json(&Json::Num(3.0)).is_err());
    }

    #[test]
    fn merged_window_is_the_union_of_frozen_windows() {
        let mut a = Metrics::default();
        a.record_batch(&[10.0], 1, 0);
        std::thread::sleep(std::time::Duration::from_millis(5));
        let mut b = Metrics::default();
        b.record_batch(&[10.0], 1, 0);
        let (wa, wb) = (a.window(), b.window());
        let mut all = Metrics::default();
        all.merge_from(&a);
        all.merge_from(&b);
        assert!(all.window() >= wa.max(wb));
        assert!(all.window() >= std::time::Duration::from_millis(5));
        assert!(all.throughput_rps() > 0.0);
        let frozen = all.throughput_rps();
        std::thread::sleep(std::time::Duration::from_millis(5));
        assert_eq!(all.throughput_rps(), frozen, "merge must not re-open");
    }
}
