//! Serving metrics: latency distribution, throughput, batch occupancy.

use std::time::Instant;

use crate::util::stats;

/// Lock-free-enough metrics (single writer — the coordinator thread).
#[derive(Debug)]
pub struct Metrics {
    started: Instant,
    latencies_us: Vec<f64>,
    batch_sizes: Vec<usize>,
    padded_rows: u64,
    errors: u64,
}

impl Default for Metrics {
    fn default() -> Self {
        Metrics {
            started: Instant::now(),
            latencies_us: Vec::new(),
            batch_sizes: Vec::new(),
            padded_rows: 0,
            errors: 0,
        }
    }
}

impl Metrics {
    /// Record one completed batch.
    pub fn record_batch(
        &mut self,
        latencies_us: &[f64],
        bucket: usize,
        padding: usize,
    ) {
        self.latencies_us.extend_from_slice(latencies_us);
        self.batch_sizes.push(bucket);
        self.padded_rows += padding as u64;
    }

    pub fn record_error(&mut self) {
        self.errors += 1;
    }

    /// Count `n` errors at once (fleet-front rejections folded into an
    /// aggregate).
    pub fn add_errors(&mut self, n: u64) {
        self.errors += n;
    }

    /// Fold another metrics record into this one (fleet aggregation:
    /// per-stream → per-shard → fleet). Keeps the earliest start so
    /// throughput spans the whole window.
    pub fn merge_from(&mut self, other: &Metrics) {
        self.started = self.started.min(other.started);
        self.latencies_us.extend_from_slice(&other.latencies_us);
        self.batch_sizes.extend_from_slice(&other.batch_sizes);
        self.padded_rows += other.padded_rows;
        self.errors += other.errors;
    }

    /// Executed padding rows (fleet padding-waste accounting).
    pub fn padded_rows(&self) -> u64 {
        self.padded_rows
    }

    /// Executed batches.
    pub fn batches(&self) -> usize {
        self.batch_sizes.len()
    }

    pub fn completed(&self) -> usize {
        self.latencies_us.len()
    }

    pub fn errors(&self) -> u64 {
        self.errors
    }

    /// Requests per second since start.
    pub fn throughput_rps(&self) -> f64 {
        let elapsed = self.started.elapsed().as_secs_f64();
        if elapsed == 0.0 {
            return 0.0;
        }
        self.completed() as f64 / elapsed
    }

    pub fn latency_percentile_us(&self, p: f64) -> f64 {
        stats::percentile(&self.latencies_us, p)
    }

    pub fn mean_latency_us(&self) -> f64 {
        stats::mean(&self.latencies_us)
    }

    /// Mean executed batch size (bucket, incl. padding).
    pub fn mean_batch_size(&self) -> f64 {
        if self.batch_sizes.is_empty() {
            return 0.0;
        }
        self.batch_sizes.iter().sum::<usize>() as f64
            / self.batch_sizes.len() as f64
    }

    /// Fraction of executed rows that were padding.
    pub fn padding_fraction(&self) -> f64 {
        let total: u64 =
            self.batch_sizes.iter().map(|&b| b as u64).sum();
        if total == 0 {
            return 0.0;
        }
        self.padded_rows as f64 / total as f64
    }

    /// Multi-line human summary.
    pub fn summary(&self) -> String {
        format!(
            "requests: {} ({} errors)\n\
             throughput: {:.1} req/s\n\
             latency µs: mean {:.0}, p50 {:.0}, p95 {:.0}, p99 {:.0}\n\
             mean batch {:.2}, padding {:.1}%",
            self.completed(),
            self.errors,
            self.throughput_rps(),
            self.mean_latency_us(),
            self.latency_percentile_us(50.0),
            self.latency_percentile_us(95.0),
            self.latency_percentile_us(99.0),
            self.mean_batch_size(),
            100.0 * self.padding_fraction(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_summarizes() {
        let mut m = Metrics::default();
        m.record_batch(&[100.0, 200.0, 300.0, 400.0], 4, 0);
        m.record_batch(&[500.0], 2, 1);
        assert_eq!(m.completed(), 5);
        assert_eq!(m.mean_latency_us(), 300.0);
        assert_eq!(m.mean_batch_size(), 3.0);
        assert!((m.padding_fraction() - 1.0 / 6.0).abs() < 1e-12);
        let s = m.summary();
        assert!(s.contains("requests: 5"));
    }

    #[test]
    fn percentiles_ordered() {
        let mut m = Metrics::default();
        let lats: Vec<f64> = (1..=100).map(|i| i as f64).collect();
        m.record_batch(&lats, 100, 0);
        assert!(m.latency_percentile_us(50.0)
            <= m.latency_percentile_us(99.0));
    }

    #[test]
    fn merge_concatenates_and_sums() {
        let mut a = Metrics::default();
        a.record_batch(&[100.0, 200.0], 4, 2);
        a.record_error();
        let mut b = Metrics::default();
        b.record_batch(&[300.0], 2, 1);
        let mut all = Metrics::default();
        all.merge_from(&a);
        all.merge_from(&b);
        all.add_errors(2);
        assert_eq!(all.completed(), 3);
        assert_eq!(all.errors(), 3);
        assert_eq!(all.batches(), 2);
        assert_eq!(all.padded_rows(), 3);
        assert_eq!(all.mean_latency_us(), 200.0);
    }

    #[test]
    fn empty_metrics_are_zero() {
        let m = Metrics::default();
        assert_eq!(m.completed(), 0);
        assert_eq!(m.mean_batch_size(), 0.0);
        assert_eq!(m.padding_fraction(), 0.0);
    }
}
