//! One shard of the fleet engine: an event loop owning its own
//! [`Router`] (the streams hash-assigned to this shard), executor,
//! waiter map, and per-stream [`Metrics`].
//!
//! This is the former single-coordinator loop, made per-shard: requests
//! arrive on the shard's channel, the router admits them into their
//! stream's batcher, and the loop sleeps until the oldest queued
//! request's batching deadline ([`IDLE_WAIT`] when every queue is
//! empty — any submit wakes `recv_timeout` immediately). Batch
//! execution is synchronous on the shard thread — PJRT CPU executions
//! are themselves multi-threaded, so one dispatch thread per shard
//! keeps per-stream ordering simple without starving the CPU; shard
//! parallelism comes from running N of these loops side by side.
//!
//! ## Work-stealing (batch-granular)
//!
//! Under a skewed stream mix one shard can saturate while its peers
//! idle. When the fleet's [`StealPolicy`] is enabled, a shard that
//! forms more ready batches in one round than `min_backlog` donates the
//! surplus — **whole formed [`BatchPlan`]s, never individual
//! requests** — to a fleet-wide deque ([`StealShared`]) and pokes an
//! idle peer. Batch *formation* stays entirely on the owning shard's
//! per-stream FIFO queues, so request→batch composition is byte-
//! identical whether stealing is on or off and for any shard count
//! (the `fleet_determinism` guarantee); stealing only relocates the
//! *execution* of already-formed batches. Each donated batch carries
//! its reply senders, and the thief records the batch on its own
//! metrics entry for that stream — the fleet front merges per-stream
//! metrics across shards on shutdown, so per-stream totals are exact
//! while per-shard metrics reflect true execution placement.

use std::collections::{BTreeMap, HashMap, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::BatchPlan;
use super::fleet::{StealPolicy, VictimSelect};
use super::metrics::Metrics;
use super::request::{InputData, Request, RequestId, Response};
use super::router::{RouteError, Router, StreamKey};
use super::server::Executor;

/// How long a shard loop may sleep when no request is queued. Purely an
/// upper bound on shutdown-by-disconnect latency: submits, pokes, and
/// shutdowns arrive on the channel and wake `recv_timeout` immediately.
pub(crate) const IDLE_WAIT: Duration = Duration::from_millis(250);

/// Published execution backlog of a shard that has shut down: never a
/// donation target again (the gauge is advisory — a stale poke is just
/// a failed send, and the donor's own shutdown drain backstops the
/// queue).
const BACKLOG_GONE: usize = usize::MAX;

/// Boxed one-shot executor constructor, invoked *inside* the shard
/// thread: PJRT executables hold thread-local handles (`Rc` internals
/// in the `xla` crate) and must never cross threads.
pub type ExecutorFactory = Box<dyn FnOnce() -> Box<dyn Executor> + Send>;

pub(crate) enum ShardMsg {
    Submit(Request, mpsc::Sender<Response>),
    /// Advisory wake-up from a donating peer: "the steal deque has
    /// work". Carries nothing — the batch lives in [`StealShared`].
    Poke,
    Shutdown,
}

/// A formed batch relocated for execution: the plan plus the reply
/// senders of its requests (pulled out of the donor's waiter map).
pub(crate) struct StolenBatch {
    pub key: StreamKey,
    pub plan: BatchPlan,
    pub waiters: HashMap<RequestId, mpsc::Sender<Response>>,
}

/// Fleet-wide stealing state shared by every shard: the ready-batch
/// deque plus per-shard execution-backlog gauges (formed batches
/// pending execution this round — *not* queued requests, which may be
/// unbatchable for a long time and say nothing about idleness).
pub(crate) struct StealShared {
    queue: Mutex<VecDeque<StolenBatch>>,
    /// Cached `queue.len()` so peers can test for work without taking
    /// the lock on every loop iteration.
    queue_len: AtomicUsize,
    backlog: Vec<AtomicUsize>,
}

impl StealShared {
    pub fn new(shards: usize) -> StealShared {
        StealShared {
            queue: Mutex::new(VecDeque::new()),
            queue_len: AtomicUsize::new(0),
            backlog: (0..shards).map(|_| AtomicUsize::new(0)).collect(),
        }
    }

    fn lock_queue(&self) -> std::sync::MutexGuard<'_, VecDeque<StolenBatch>> {
        // a panicking executor can never poison this lock (batches are
        // executed after the guard drops), but stay robust anyway
        self.queue.lock().unwrap_or_else(|e| e.into_inner())
    }

    fn push(&self, batch: StolenBatch) {
        let mut q = self.lock_queue();
        q.push_back(batch);
        self.queue_len.store(q.len(), Ordering::Release);
    }

    fn pop(&self) -> Option<StolenBatch> {
        if self.queue_len.load(Ordering::Acquire) == 0 {
            return None;
        }
        let mut q = self.lock_queue();
        let batch = q.pop_front();
        self.queue_len.store(q.len(), Ordering::Release);
        batch
    }

    fn pending(&self) -> usize {
        self.queue_len.load(Ordering::Acquire)
    }
}

/// Per-shard stealing context: identity, policy, the shared state, and
/// peer channels for pokes. `peers` is empty when stealing is disabled,
/// so the disabled path has no channel cycle between shard threads and
/// keeps the legacy disconnect-to-exit behavior.
pub(crate) struct StealCtx {
    pub index: usize,
    pub policy: StealPolicy,
    pub shared: Arc<StealShared>,
    pub peers: Vec<mpsc::Sender<ShardMsg>>,
    next_rr: usize,
}

impl StealCtx {
    /// A context that never donates nor steals (single-coordinator and
    /// stealing-off fleets).
    pub fn disabled(index: usize) -> StealCtx {
        StealCtx {
            index,
            policy: StealPolicy::default(),
            shared: Arc::new(StealShared::new(1)),
            peers: Vec::new(),
            next_rr: 0,
        }
    }

    pub fn enabled(
        index: usize,
        policy: StealPolicy,
        shared: Arc<StealShared>,
        peers: Vec<mpsc::Sender<ShardMsg>>,
    ) -> StealCtx {
        StealCtx { index, policy, shared, peers, next_rr: 0 }
    }

    fn stealing(&self) -> bool {
        self.policy.enabled && !self.peers.is_empty()
    }

    fn publish_backlog(&self, batches: usize) {
        if self.stealing() {
            // lint:allow(panic-path): StealShared::new(n) sizes backlog to the shard count, and index < n by construction in LocalTransport::spawn
            self.shared.backlog[self.index].store(batches, Ordering::Release);
        }
    }

    /// The peer to poke for a donation. Donations only target *idle*
    /// peers (published execution backlog 0): parking batches on the
    /// deque while every shard is busy would starve them, since a
    /// saturated shard services its own streams before stealing.
    /// `None` when every peer is busy — the donor then executes the
    /// batch itself. Selection among candidates follows the policy:
    /// `LeastLoaded` takes the minimum-backlog peer (ties → lowest
    /// index) and donates only if that minimum is 0; `RoundRobin`
    /// rotates across idle peers so consecutive donations wake
    /// different thieves.
    fn pick_idle_peer(&mut self) -> Option<usize> {
        let n = self.peers.len();
        let me = self.index;
        let shared = &self.shared;
        // lint:allow(panic-path): i ranges over 0..peers.len() == backlog.len() (both sized to the shard count)
        let load = move |i: usize| shared.backlog[i].load(Ordering::Acquire);
        match self.policy.victim {
            VictimSelect::LeastLoaded => (0..n)
                .filter(|&i| i != me)
                .min_by_key(|&i| load(i))
                .filter(|&i| load(i) == 0),
            VictimSelect::RoundRobin => {
                for step in 0..n {
                    let i = (self.next_rr + step) % n;
                    if i != me && load(i) == 0 {
                        self.next_rr = (i + 1) % n;
                        return Some(i);
                    }
                }
                None
            }
        }
    }

    fn donate(&self, batch: StolenBatch, thief: usize) {
        self.shared.push(batch);
        // advisory: a dead peer just fails the send; the deque (and
        // every shard's shutdown drain) still owns the batch
        // lint:allow(panic-path): thief comes from pick_idle_peer, which scans 0..peers.len()
        let _ = self.peers[thief].send(ShardMsg::Poke);
    }
}

/// Final accounting a shard returns on shutdown — the report half of
/// the [`ShardTransport`] contract (re-exported from
/// `coordinator::transport`): thread-backed shards return it on join,
/// process-backed shards ship it back as the wire protocol's
/// `metrics_snapshot` frame.
///
/// [`ShardTransport`]: super::transport::ShardTransport
pub struct ShardReport {
    /// Metrics per stream *executed* on this shard: every stream it
    /// owns (even with zero traffic), plus entries for foreign streams
    /// whose stolen batches it ran. The fleet front merges these across
    /// shards into exact per-stream totals.
    pub streams: BTreeMap<StreamKey, Metrics>,
    /// Requests that reached this shard for a stream it does not own.
    pub rejected: u64,
    /// Donated batches this shard executed for overloaded peers.
    pub stolen: u64,
    /// Formed batches this shard handed to the steal deque.
    pub donated: u64,
}

pub(crate) struct ShardHandle {
    pub tx: mpsc::Sender<ShardMsg>,
    pub handle: JoinHandle<ShardReport>,
}

/// Spawn one shard event loop over the given routing table.
pub(crate) fn start_shard(
    router: Router,
    make_executor: ExecutorFactory,
) -> ShardHandle {
    let (tx, rx) = mpsc::channel::<ShardMsg>();
    let ctx = StealCtx::disabled(0);
    let handle = std::thread::spawn(move || {
        shard_loop(router, make_executor, rx, ctx)
    });
    ShardHandle { tx, handle }
}

/// Spawn one shard event loop with an explicit stealing context and a
/// pre-built channel (the fleet front creates all channels first so
/// every shard can hold its peers' senders).
pub(crate) fn start_shard_with(
    router: Router,
    make_executor: ExecutorFactory,
    tx: mpsc::Sender<ShardMsg>,
    rx: mpsc::Receiver<ShardMsg>,
    ctx: StealCtx,
) -> ShardHandle {
    let handle = std::thread::spawn(move || {
        shard_loop(router, make_executor, rx, ctx)
    });
    ShardHandle { tx, handle }
}

fn shard_loop(
    mut router: Router,
    make_executor: ExecutorFactory,
    rx: mpsc::Receiver<ShardMsg>,
    mut ctx: StealCtx,
) -> ShardReport {
    let mut executor = make_executor();
    let mut streams: BTreeMap<StreamKey, Metrics> = router
        .streams()
        .into_iter()
        .map(|key| (key, Metrics::default()))
        .collect();
    let mut rejected = 0u64;
    let mut stolen = 0u64;
    let mut donated = 0u64;
    let mut waiters: HashMap<RequestId, mpsc::Sender<Response>> =
        HashMap::new();
    let mut inputs: Vec<Arc<InputData>> = Vec::new();
    let finish = |router: &mut Router,
                  executor: &mut Box<dyn Executor>,
                  streams: &mut BTreeMap<StreamKey, Metrics>,
                  waiters: &mut HashMap<RequestId, mpsc::Sender<Response>>,
                  inputs: &mut Vec<Arc<InputData>>,
                  ctx: &StealCtx,
                  stolen: &mut u64| {
        // never a donation target again; then run everything left:
        // our own queues, and whatever sits in the steal deque (our
        // own unclaimed donations included — nothing is ever lost)
        ctx.publish_backlog(BACKLOG_GONE);
        flush_all(router, &mut **executor, streams, waiters, inputs);
        if ctx.stealing() {
            while let Some(batch) = ctx.shared.pop() {
                exec_stolen(batch, &mut **executor, streams, inputs);
                *stolen += 1;
            }
        }
    };
    loop {
        // Sleep until the oldest queued request needs a timeout-based
        // batch; skip the sleep entirely while the steal deque holds
        // work; idle indefinitely (modulo IDLE_WAIT) otherwise.
        let wait = if ctx.stealing() && ctx.shared.pending() > 0 {
            Duration::ZERO
        } else {
            router.next_deadline(Instant::now()).unwrap_or(IDLE_WAIT)
        };
        match rx.recv_timeout(wait) {
            Ok(ShardMsg::Submit(req, reply)) => {
                admit(&mut router, req, reply, &mut streams, &mut rejected,
                      &mut waiters);
            }
            Ok(ShardMsg::Poke) => {}
            Ok(ShardMsg::Shutdown) => {
                finish(&mut router, &mut executor, &mut streams,
                       &mut waiters, &mut inputs, &ctx, &mut stolen);
                return ShardReport { streams, rejected, stolen, donated };
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                finish(&mut router, &mut executor, &mut streams,
                       &mut waiters, &mut inputs, &ctx, &mut stolen);
                return ShardReport { streams, rejected, stolen, donated };
            }
        }
        // Drain the whole backlog before forming batches so a burst
        // fills real buckets instead of timeout-firing as singles
        // (arrivals are cheap; batches are not).
        while let Ok(msg) = rx.try_recv() {
            match msg {
                ShardMsg::Submit(req, reply) => {
                    admit(&mut router, req, reply, &mut streams,
                          &mut rejected, &mut waiters);
                }
                ShardMsg::Poke => {}
                ShardMsg::Shutdown => {
                    finish(&mut router, &mut executor, &mut streams,
                           &mut waiters, &mut inputs, &ctx, &mut stolen);
                    return ShardReport { streams, rejected, stolen, donated };
                }
            }
        }
        let mut ready = router.ready_batches(Instant::now());
        // Donor: keep `min_backlog` of this round's batches, hand the
        // surplus to idle peers *in formation order* (so a stream's
        // donated batches drain the deque oldest-first). Formation
        // already happened — only the execution site moves, so
        // composition is steal-invariant.
        if ctx.stealing() && ready.len() > ctx.policy.min_backlog {
            let surplus = ready.split_off(ctx.policy.min_backlog);
            for (key, plan) in surplus {
                let Some(thief) = ctx.pick_idle_peer() else {
                    // every peer busy: execute the rest ourselves
                    ready.push((key, plan));
                    continue;
                };
                let batch_waiters = plan
                    .requests
                    .iter()
                    .filter_map(|r| {
                        waiters.remove(&r.id).map(|tx| (r.id, tx))
                    })
                    .collect();
                ctx.donate(
                    StolenBatch { key, plan, waiters: batch_waiters },
                    thief,
                );
                donated += 1;
            }
        }
        ctx.publish_backlog(ready.len());
        for (key, plan) in ready {
            let metrics =
                // lint:allow(panic-path): the router only forms batches for streams registered at shard startup; a miss is a shard bug, and the panic surfaces as ShardPanic at shutdown
                streams.get_mut(&key).expect("batch from registered stream");
            run_batch(&key, plan, &mut *executor, metrics, &mut waiters,
                      &mut inputs);
        }
        ctx.publish_backlog(0);
        // Thief: with no batch of our own due, execute one donated
        // batch per iteration (the channel is re-drained in between, so
        // local admissions never starve behind a long steal run).
        if ctx.stealing()
            && router
                .next_deadline(Instant::now())
                .map_or(true, |d| d > Duration::ZERO)
        {
            if let Some(batch) = ctx.shared.pop() {
                exec_stolen(batch, &mut *executor, &mut streams, &mut inputs);
                stolen += 1;
            }
        }
    }
}

/// Route one submission; rejections drop the reply sender (the caller's
/// `recv` fails immediately instead of leaking a waiter) and are
/// recorded — on the stream for admission-control rejections, on the
/// shard for unknown streams.
fn admit(
    router: &mut Router,
    req: Request,
    reply: mpsc::Sender<Response>,
    streams: &mut BTreeMap<StreamKey, Metrics>,
    rejected: &mut u64,
    waiters: &mut HashMap<RequestId, mpsc::Sender<Response>>,
) {
    let id = req.id;
    match router.route(req) {
        Ok(()) => {
            waiters.insert(id, reply);
        }
        Err(RouteError::QueueFull { stream, .. }) => {
            match streams.get_mut(&stream) {
                Some(m) => m.record_error(),
                None => *rejected += 1,
            }
        }
        // UnknownStream; ShardDown is front-side only, never from route()
        Err(_) => *rejected += 1,
    }
}

fn flush_all(
    router: &mut Router,
    executor: &mut dyn Executor,
    streams: &mut BTreeMap<StreamKey, Metrics>,
    waiters: &mut HashMap<RequestId, mpsc::Sender<Response>>,
    inputs: &mut Vec<Arc<InputData>>,
) {
    for (key, plan) in router.flush() {
        let metrics =
            // lint:allow(panic-path): the router only forms batches for streams registered at shard startup; a miss is a shard bug, and the panic surfaces as ShardPanic at shutdown
            streams.get_mut(&key).expect("batch from registered stream");
        run_batch(&key, plan, executor, metrics, waiters, inputs);
    }
}

/// Execute one donated batch on the thief shard: its reply senders
/// travel with the plan, and the batch lands on this shard's metrics
/// entry for the stream (created on demand — the fleet front merges
/// per-stream entries across shards).
fn exec_stolen(
    batch: StolenBatch,
    executor: &mut dyn Executor,
    streams: &mut BTreeMap<StreamKey, Metrics>,
    inputs: &mut Vec<Arc<InputData>>,
) {
    let StolenBatch { key, plan, mut waiters } = batch;
    let metrics = streams.entry(key.clone()).or_default();
    run_batch(&key, plan, executor, metrics, &mut waiters, inputs);
}

fn run_batch(
    key: &StreamKey,
    plan: BatchPlan,
    executor: &mut dyn Executor,
    metrics: &mut Metrics,
    waiters: &mut HashMap<RequestId, mpsc::Sender<Response>>,
    inputs: &mut Vec<Arc<InputData>>,
) {
    inputs.clear();
    inputs.extend(plan.requests.iter().map(|r| r.input.clone()));
    match executor.execute(key, inputs, plan.bucket) {
        // An executor must answer every request it was handed. A short
        // (or long) output vector is a *batch* error: the old zip
        // silently skipped trailing requests, leaking their waiters
        // until the caller's full recv timeout with no error recorded.
        Ok(outputs) if outputs.len() == plan.requests.len() => {
            let now = Instant::now();
            let mut lats = Vec::with_capacity(plan.requests.len());
            for (req, output) in plan.requests.iter().zip(outputs) {
                let latency_us =
                    now.duration_since(req.enqueued).as_secs_f64() * 1e6;
                lats.push(latency_us);
                if let Some(reply) = waiters.remove(&req.id) {
                    let _ = reply.send(Response {
                        id: req.id,
                        output,
                        latency_us,
                        batch_size: plan.bucket,
                    });
                }
            }
            metrics.record_batch(&lats, plan.bucket, plan.padding());
        }
        Ok(_) | Err(_) => {
            for req in &plan.requests {
                metrics.record_error();
                // drop sender → Err on the caller's recv, immediately
                waiters.remove(&req.id);
            }
        }
    }
}
