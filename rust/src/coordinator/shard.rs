//! One shard of the fleet engine: an event loop owning its own
//! [`Router`] (the streams hash-assigned to this shard), executor,
//! waiter map, and per-stream [`Metrics`].
//!
//! This is the former single-coordinator loop, made per-shard: requests
//! arrive on the shard's channel, the router admits them into their
//! stream's batcher, and the loop sleeps until the oldest queued
//! request's batching deadline ([`IDLE_WAIT`] when every queue is
//! empty — any submit wakes `recv_timeout` immediately). Batch
//! execution is synchronous on the shard thread — PJRT CPU executions
//! are themselves multi-threaded, so one dispatch thread per shard
//! keeps per-stream ordering simple without starving the CPU; shard
//! parallelism comes from running N of these loops side by side.

use std::collections::{BTreeMap, HashMap};
use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use super::batcher::BatchPlan;
use super::metrics::Metrics;
use super::request::{InputData, Request, RequestId, Response};
use super::router::{RouteError, Router, StreamKey};
use super::server::Executor;

/// How long a shard loop may sleep when no request is queued. Purely an
/// upper bound on shutdown-by-disconnect latency: submits and shutdowns
/// arrive on the channel and wake `recv_timeout` immediately.
pub(crate) const IDLE_WAIT: Duration = Duration::from_millis(250);

/// Boxed one-shot executor constructor, invoked *inside* the shard
/// thread: PJRT executables hold thread-local handles (`Rc` internals
/// in the `xla` crate) and must never cross threads.
pub type ExecutorFactory = Box<dyn FnOnce() -> Box<dyn Executor> + Send>;

pub(crate) enum ShardMsg {
    Submit(Request, mpsc::Sender<Response>),
    Shutdown,
}

/// Final accounting a shard thread returns on join.
pub(crate) struct ShardReport {
    /// Metrics per stream owned by this shard (every registered stream
    /// appears, even with zero traffic).
    pub streams: BTreeMap<StreamKey, Metrics>,
    /// Requests that reached this shard for a stream it does not own.
    pub rejected: u64,
}

pub(crate) struct ShardHandle {
    pub tx: mpsc::Sender<ShardMsg>,
    pub handle: JoinHandle<ShardReport>,
}

/// Spawn one shard event loop over the given routing table.
pub(crate) fn start_shard(
    router: Router,
    make_executor: ExecutorFactory,
) -> ShardHandle {
    let (tx, rx) = mpsc::channel::<ShardMsg>();
    let handle =
        std::thread::spawn(move || shard_loop(router, make_executor, rx));
    ShardHandle { tx, handle }
}

fn shard_loop(
    mut router: Router,
    make_executor: ExecutorFactory,
    rx: mpsc::Receiver<ShardMsg>,
) -> ShardReport {
    let mut executor = make_executor();
    let mut streams: BTreeMap<StreamKey, Metrics> = router
        .streams()
        .into_iter()
        .map(|key| (key, Metrics::default()))
        .collect();
    let mut rejected = 0u64;
    let mut waiters: HashMap<RequestId, mpsc::Sender<Response>> =
        HashMap::new();
    let mut inputs: Vec<Arc<InputData>> = Vec::new();
    loop {
        // Sleep until the oldest queued request needs a timeout-based
        // batch; idle indefinitely (modulo IDLE_WAIT) when no queue
        // holds work.
        let wait = router.next_deadline(Instant::now()).unwrap_or(IDLE_WAIT);
        match rx.recv_timeout(wait) {
            Ok(ShardMsg::Submit(req, reply)) => {
                admit(&mut router, req, reply, &mut streams, &mut rejected,
                      &mut waiters);
            }
            Ok(ShardMsg::Shutdown) => {
                flush_all(&mut router, &mut *executor, &mut streams,
                          &mut waiters, &mut inputs);
                return ShardReport { streams, rejected };
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                return ShardReport { streams, rejected };
            }
        }
        // Drain the whole backlog before forming batches so a burst
        // fills real buckets instead of timeout-firing as singles
        // (arrivals are cheap; batches are not).
        while let Ok(msg) = rx.try_recv() {
            match msg {
                ShardMsg::Submit(req, reply) => {
                    admit(&mut router, req, reply, &mut streams,
                          &mut rejected, &mut waiters);
                }
                ShardMsg::Shutdown => {
                    flush_all(&mut router, &mut *executor, &mut streams,
                              &mut waiters, &mut inputs);
                    return ShardReport { streams, rejected };
                }
            }
        }
        for (key, plan) in router.ready_batches(Instant::now()) {
            let metrics =
                streams.get_mut(&key).expect("batch from registered stream");
            run_batch(&key, plan, &mut *executor, metrics, &mut waiters,
                      &mut inputs);
        }
    }
}

/// Route one submission; rejections drop the reply sender (the caller's
/// `recv` fails immediately instead of leaking a waiter) and are
/// recorded — on the stream for admission-control rejections, on the
/// shard for unknown streams.
fn admit(
    router: &mut Router,
    req: Request,
    reply: mpsc::Sender<Response>,
    streams: &mut BTreeMap<StreamKey, Metrics>,
    rejected: &mut u64,
    waiters: &mut HashMap<RequestId, mpsc::Sender<Response>>,
) {
    let id = req.id;
    match router.route(req) {
        Ok(()) => {
            waiters.insert(id, reply);
        }
        Err(RouteError::QueueFull { stream, .. }) => {
            match streams.get_mut(&stream) {
                Some(m) => m.record_error(),
                None => *rejected += 1,
            }
        }
        Err(RouteError::UnknownStream(_)) => *rejected += 1,
    }
}

fn flush_all(
    router: &mut Router,
    executor: &mut dyn Executor,
    streams: &mut BTreeMap<StreamKey, Metrics>,
    waiters: &mut HashMap<RequestId, mpsc::Sender<Response>>,
    inputs: &mut Vec<Arc<InputData>>,
) {
    for (key, plan) in router.flush() {
        let metrics =
            streams.get_mut(&key).expect("batch from registered stream");
        run_batch(&key, plan, executor, metrics, waiters, inputs);
    }
}

fn run_batch(
    key: &StreamKey,
    plan: BatchPlan,
    executor: &mut dyn Executor,
    metrics: &mut Metrics,
    waiters: &mut HashMap<RequestId, mpsc::Sender<Response>>,
    inputs: &mut Vec<Arc<InputData>>,
) {
    inputs.clear();
    inputs.extend(plan.requests.iter().map(|r| r.input.clone()));
    match executor.execute(key, inputs, plan.bucket) {
        Ok(outputs) => {
            let now = Instant::now();
            let mut lats = Vec::with_capacity(plan.requests.len());
            for (req, output) in plan.requests.iter().zip(outputs) {
                let latency_us =
                    now.duration_since(req.enqueued).as_secs_f64() * 1e6;
                lats.push(latency_us);
                if let Some(reply) = waiters.remove(&req.id) {
                    let _ = reply.send(Response {
                        id: req.id,
                        output,
                        latency_us,
                        batch_size: plan.bucket,
                    });
                }
            }
            metrics.record_batch(&lats, plan.bucket, plan.padding());
        }
        Err(_) => {
            for req in &plan.requests {
                metrics.record_error();
                // drop sender → Err on the caller's recv
                waiters.remove(&req.id);
            }
        }
    }
}
