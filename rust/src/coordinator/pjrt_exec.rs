//! PJRT-backed [`Executor`]: the production bridge from the coordinator
//! to the AOT artifacts (one compiled executable per (stream, bucket)).

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{anyhow, bail, Result};

use super::request::InputData;
use super::router::StreamKey;
use super::server::Executor;
use crate::runtime::{Engine, LoadedModel};

/// Executor holding pre-compiled executables for every registered
/// (family, k, bucket) combination. Keys are `Arc<str>` like the stream
/// keys (matched by content, not pointer), so dispatch lookup clones a
/// refcounted handle instead of copying the family name per batch.
pub struct PjrtExecutor {
    models: HashMap<(Arc<str>, usize, usize), LoadedModel>,
}

impl PjrtExecutor {
    /// Compile executables for the given streams at all their bucket
    /// sizes. Done once at startup — the serve path never compiles.
    pub fn preload(
        engine: &Engine,
        streams: &[(String, usize, Vec<usize>)],
    ) -> Result<PjrtExecutor> {
        let mut models = HashMap::new();
        for (family, k, buckets) in streams {
            let family: Arc<str> = Arc::from(family.as_str());
            for &b in buckets {
                let lm = engine.load(&family, *k, b)?;
                models.insert((family.clone(), *k, b), lm);
            }
        }
        Ok(PjrtExecutor { models })
    }

    pub fn loaded(&self) -> usize {
        self.models.len()
    }
}

impl Executor for PjrtExecutor {
    fn execute(
        &mut self,
        stream: &StreamKey,
        inputs: &[Arc<InputData>],
        bucket: usize,
    ) -> Result<Vec<Vec<f32>>> {
        let key = (stream.0.clone(), stream.1, bucket);
        let model = self
            .models
            .get(&key)
            .ok_or_else(|| anyhow!("no executable for {key:?}"))?;
        if inputs.is_empty() || inputs.len() > bucket {
            bail!("batch of {} for bucket {bucket}", inputs.len());
        }

        let per_sample = model.input_len() / bucket;
        let out_per_sample = model.output_len() / bucket;

        // Flatten + pad by repeating the last sample (discarded below).
        let raw = match &*inputs[0] {
            InputData::F32(_) => {
                let mut flat = Vec::with_capacity(model.input_len());
                for i in 0..bucket {
                    let sample = inputs.get(i).unwrap_or(
                        // lint:allow(panic-path): the is_empty() bail above guarantees at least one sample
                        inputs.last().expect("nonempty"),
                    );
                    match &**sample {
                        InputData::F32(v) => {
                            if v.len() != per_sample {
                                bail!(
                                    "sample len {} != expected {per_sample}",
                                    v.len()
                                );
                            }
                            flat.extend_from_slice(v);
                        }
                        _ => bail!("mixed dtypes in batch"),
                    }
                }
                model.run_f32(&flat)?
            }
            InputData::I32(_) => {
                let mut flat = Vec::with_capacity(model.input_len());
                for i in 0..bucket {
                    let sample = inputs.get(i).unwrap_or(
                        // lint:allow(panic-path): the is_empty() bail above guarantees at least one sample
                        inputs.last().expect("nonempty"),
                    );
                    match &**sample {
                        InputData::I32(v) => {
                            if v.len() != per_sample {
                                bail!(
                                    "sample len {} != expected {per_sample}",
                                    v.len()
                                );
                            }
                            flat.extend_from_slice(v);
                        }
                        _ => bail!("mixed dtypes in batch"),
                    }
                }
                model.run_i32(&flat)?
            }
        };

        // Split the batch output back into per-sample slices.
        Ok(inputs
            .iter()
            .enumerate()
            .map(|(i, _)| {
                raw[i * out_per_sample..(i + 1) * out_per_sample].to_vec()
            })
            .collect())
    }
}
