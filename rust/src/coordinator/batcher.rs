//! Dynamic batcher with bucketed batch sizes.
//!
//! One AOT executable exists per batch size (the PJRT serving pattern:
//! static shapes, bucketed batching). The batcher keeps one FIFO queue
//! per (family, k) and forms a batch when either (a) the queue can fill
//! the largest bucket, or (b) the oldest request has waited longer than
//! `max_wait`, in which case the largest bucket ≤ queue length is used
//! and the remainder padded with a repeat of the last request's input
//! (padding rows are discarded on output).

use std::collections::VecDeque;
use std::time::{Duration, Instant};

use super::request::Request;

/// Batching policy knobs.
#[derive(Clone, Debug)]
pub struct BatcherConfig {
    /// Available bucket sizes, ascending (from the manifest).
    pub buckets: Vec<usize>,
    /// Max time the oldest request may wait before a partial batch fires.
    pub max_wait: Duration,
    /// Admission control: max queued requests before new arrivals are
    /// rejected (0 = unbounded).
    pub max_queue: usize,
}

impl BatcherConfig {
    pub fn new(mut buckets: Vec<usize>, max_wait: Duration) -> Self {
        buckets.sort_unstable();
        buckets.dedup();
        // lint:allow(panic-path): construction-time invariant — config validation rejects empty bucket lists before a batcher exists
        assert!(!buckets.is_empty(), "need at least one bucket size");
        BatcherConfig { buckets, max_wait, max_queue: 0 }
    }

    /// Bound the queue depth (admission control); 0 keeps it unbounded.
    pub fn with_max_queue(mut self, max_queue: usize) -> Self {
        self.max_queue = max_queue;
        self
    }

    /// Largest bucket ≤ n, or the smallest bucket when n is tiny.
    pub fn bucket_for(&self, n: usize) -> usize {
        self.buckets
            .iter()
            .rev()
            .find(|&&b| b <= n)
            .copied()
            .unwrap_or(self.buckets[0])
    }

    pub fn max_bucket(&self) -> usize {
        // lint:allow(panic-path): buckets is non-empty by the constructor assert above
        *self.buckets.last().unwrap()
    }
}

/// A formed batch: the requests to run plus padding count.
#[derive(Debug)]
pub struct BatchPlan {
    pub requests: Vec<Request>,
    /// Executable batch size (≥ requests.len()).
    pub bucket: usize,
}

impl BatchPlan {
    pub fn padding(&self) -> usize {
        self.bucket - self.requests.len()
    }
}

/// FIFO queue + batch forming for one (family, k) stream.
#[derive(Debug)]
pub struct Batcher {
    config: BatcherConfig,
    queue: VecDeque<Request>,
    /// Total requests admitted (conservation checks).
    pub admitted: u64,
    /// Total requests emitted in batches.
    pub emitted: u64,
}

impl Batcher {
    pub fn new(config: BatcherConfig) -> Batcher {
        Batcher { config, queue: VecDeque::new(), admitted: 0, emitted: 0 }
    }

    /// Admit one request; returns `false` (request dropped) when the
    /// queue is at its admission bound.
    pub fn push(&mut self, r: Request) -> bool {
        if self.config.max_queue != 0
            && self.queue.len() >= self.config.max_queue
        {
            return false;
        }
        self.admitted += 1;
        self.queue.push_back(r);
        true
    }

    /// The policy this batcher enforces.
    pub fn config(&self) -> &BatcherConfig {
        &self.config
    }

    pub fn len(&self) -> usize {
        self.queue.len()
    }

    pub fn is_empty(&self) -> bool {
        self.queue.is_empty()
    }

    /// Age of the oldest queued request.
    pub fn oldest_wait(&self, now: Instant) -> Duration {
        self.queue
            .front()
            .map(|r| now.duration_since(r.enqueued))
            .unwrap_or(Duration::ZERO)
    }

    /// Time until the oldest queued request hits `max_wait` (zero once
    /// expired), or `None` when the queue is empty — how long the
    /// coordinator may sleep before this stream needs service. Derived
    /// from [`Self::oldest_wait`] so the sleep bound and `pop_batch`'s
    /// expiry test can never diverge.
    pub fn deadline_in(&self, now: Instant) -> Option<Duration> {
        if self.queue.is_empty() {
            return None;
        }
        Some(self.config.max_wait.saturating_sub(self.oldest_wait(now)))
    }

    /// Form a batch if the policy allows; `now` injected for testability.
    pub fn pop_batch(&mut self, now: Instant) -> Option<BatchPlan> {
        if self.queue.is_empty() {
            return None;
        }
        let full = self.queue.len() >= self.config.max_bucket();
        let expired = self.oldest_wait(now) >= self.config.max_wait;
        if !full && !expired {
            return None;
        }
        let bucket = self.config.bucket_for(self.queue.len());
        let take = bucket.min(self.queue.len());
        let requests: Vec<Request> =
            self.queue.drain(..take).collect();
        self.emitted += requests.len() as u64;
        Some(BatchPlan { requests, bucket })
    }

    /// Drain everything immediately (shutdown path).
    pub fn flush(&mut self) -> Vec<BatchPlan> {
        let mut plans = Vec::new();
        while !self.queue.is_empty() {
            let bucket = self.config.bucket_for(self.queue.len());
            let take = bucket.min(self.queue.len());
            let requests: Vec<Request> = self.queue.drain(..take).collect();
            self.emitted += requests.len() as u64;
            plans.push(BatchPlan { requests, bucket });
        }
        plans
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::InputData;

    fn req(id: u64) -> Request {
        Request::new(id, "bert", 5, InputData::I32(vec![0; 8]))
    }

    fn cfg(buckets: &[usize], wait_ms: u64) -> BatcherConfig {
        BatcherConfig::new(buckets.to_vec(), Duration::from_millis(wait_ms))
    }

    #[test]
    fn bucket_selection() {
        let c = cfg(&[1, 2, 4, 8, 16], 10);
        assert_eq!(c.bucket_for(16), 16);
        assert_eq!(c.bucket_for(9), 8);
        assert_eq!(c.bucket_for(3), 2);
        assert_eq!(c.bucket_for(0), 1);
    }

    #[test]
    fn fires_when_full() {
        let mut b = Batcher::new(cfg(&[1, 2, 4], 1000));
        let now = Instant::now();
        for i in 0..3 {
            b.push(req(i));
            assert!(b.pop_batch(now).is_none(), "fired early at {i}");
        }
        b.push(req(3));
        let plan = b.pop_batch(now).expect("full batch fires");
        assert_eq!(plan.bucket, 4);
        assert_eq!(plan.requests.len(), 4);
        assert_eq!(plan.padding(), 0);
    }

    #[test]
    fn fires_on_timeout_with_padding() {
        let mut b = Batcher::new(cfg(&[1, 2, 4], 0));
        b.push(req(0));
        b.push(req(1));
        b.push(req(2));
        let plan = b.pop_batch(Instant::now()).expect("timeout fires");
        assert_eq!(plan.bucket, 2); // largest bucket ≤ 3
        assert_eq!(plan.requests.len(), 2);
    }

    #[test]
    fn preserves_fifo() {
        let mut b = Batcher::new(cfg(&[1, 2, 4], 0));
        for i in 0..7 {
            b.push(req(i));
        }
        let mut seen = Vec::new();
        let now = Instant::now();
        while let Some(plan) = b.pop_batch(now) {
            seen.extend(plan.requests.iter().map(|r| r.id));
        }
        assert_eq!(seen, (0..7).collect::<Vec<u64>>());
    }

    #[test]
    fn deadline_in_counts_down_and_saturates() {
        let mut b = Batcher::new(cfg(&[4], 50));
        let now = Instant::now();
        assert_eq!(b.deadline_in(now), None, "empty queue has no deadline");
        b.push(req(0));
        let d = b.deadline_in(Instant::now()).expect("queued");
        assert!(d <= Duration::from_millis(50));
        let later = Instant::now() + Duration::from_millis(200);
        assert_eq!(b.deadline_in(later), Some(Duration::ZERO));
    }

    #[test]
    fn flush_conserves_requests() {
        let mut b = Batcher::new(cfg(&[4, 8], 1_000_000));
        for i in 0..13 {
            b.push(req(i));
        }
        let total: usize =
            b.flush().iter().map(|p| p.requests.len()).sum();
        assert_eq!(total, 13);
        assert_eq!(b.admitted, 13);
        assert_eq!(b.emitted, 13);
        assert!(b.is_empty());
    }

    #[test]
    fn max_queue_bounds_admission() {
        let mut b =
            Batcher::new(cfg(&[4], 1_000_000).with_max_queue(3));
        assert!(b.push(req(0)));
        assert!(b.push(req(1)));
        assert!(b.push(req(2)));
        assert!(!b.push(req(3)), "queue at bound must reject");
        assert_eq!(b.len(), 3);
        assert_eq!(b.admitted, 3);
        // draining frees capacity again
        let drained: usize =
            b.flush().iter().map(|p| p.requests.len()).sum();
        assert_eq!(drained, 3);
        assert!(b.push(req(4)));
    }

    #[test]
    fn property_batcher_invariants() {
        use crate::util::{check::property, rng::Rng};
        property("batcher: capacity, fifo, conservation", 200, 0xBA7C, |rng: &mut Rng| {
            let n_buckets = 1 + rng.below(4);
            let mut buckets: Vec<usize> =
                (0..n_buckets).map(|_| 1 << rng.below(6)).collect();
            buckets.push(1); // always a unit bucket
            let c = BatcherConfig::new(buckets, Duration::ZERO);
            let max_bucket = c.max_bucket();
            let mut b = Batcher::new(c);
            let n = rng.below(100);
            for i in 0..n {
                b.push(req(i as u64));
            }
            let mut out = Vec::new();
            let now = Instant::now();
            while let Some(plan) = b.pop_batch(now) {
                crate::prop_assert!(
                    plan.requests.len() <= plan.bucket,
                    "overfilled bucket: {} > {}",
                    plan.requests.len(), plan.bucket
                );
                crate::prop_assert!(
                    plan.bucket <= max_bucket,
                    "bucket {} over max {}", plan.bucket, max_bucket
                );
                out.extend(plan.requests.iter().map(|r| r.id));
            }
            let want: Vec<u64> = (0..n as u64).collect();
            crate::prop_assert!(out == want, "fifo violated or lost: {:?}", out);
            crate::prop_assert!(
                b.admitted == b.emitted,
                "conservation: admitted {} emitted {}", b.admitted, b.emitted
            );
            Ok(())
        });
    }
}
