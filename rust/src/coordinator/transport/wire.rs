//! The fleet↔shard wire protocol: versioned, length-prefixed JSONL
//! frames (the process transport's contract, `transport/proc.rs`).
//!
//! Every frame on the pipe is one line of the form
//!
//! ```text
//! <len> <json>\n
//! ```
//!
//! where `<len>` is the byte length of `<json>` in ASCII decimal. The
//! prefix makes truncation detectable (a killed worker cannot leave a
//! frame that parses by accident) and keeps the stream seekable without
//! trusting embedded newlines. Handshake frames (`init`, `ready`) carry
//! `format`/`version` and are rejected loudly on skew, matching the
//! `trace.rs` conventions; unknown frame kinds and unknown fields are
//! errors, never guesses.
//!
//! Frame kinds (`kind` field):
//!
//! | kind               | direction        | payload |
//! |--------------------|------------------|---------|
//! | `join`             | worker → front   | membership dial-in: worker pid (version-checked) |
//! | `init`             | front → worker   | shard index/count, executor choice, full `StackConfig` JSON |
//! | `ready`            | worker → front   | handshake ack (version-checked) |
//! | `submit`           | front → worker   | request id + stream key + input payload |
//! | `reply`            | worker → front   | per-request output, or a typed error |
//! | `poke`             | front → worker   | advisory wake-up (steal protocol) |
//! | `donate`           | either           | a formed batch relocated for execution (steal protocol) |
//! | `steal`            | worker → front   | request for donated work (steal protocol) |
//! | `heartbeat`        | worker → front   | periodic liveness beacon (membership) |
//! | `leave`            | worker → front   | voluntary departure announcement; drain follows |
//! | `metrics_snapshot` | worker → front   | final [`ShardReport`]: per-stream metrics + counters |
//! | `shutdown`         | front → worker   | drain queues, snapshot, exit |
//! | `fatal`            | either           | unrecoverable protocol failure, then close |
//!
//! `donate`/`steal`/`poke` define the stealing half of the protocol,
//! mediated by the front (DESIGN.md §16): an idle worker announces
//! hunger with `steal`, a loaded worker ships surplus formed batches as
//! `donate`, and the front forwards each donation to a hungry worker —
//! or straight back to the donor when nobody is hungry, so no request
//! is ever lost in flight. `join`/`heartbeat`/`leave` are the elastic
//! membership half used by the TCP transport; the pipe transport's
//! workers are spawned, not dialed, so they skip `join` and never
//! heartbeat (a pipe EOF is already a synchronous death signal).
//!
//! [`ShardReport`]: super::ShardReport

use std::fmt;
use std::io::{BufRead, Write};
use std::sync::Arc;

use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InputData, RequestId};
use crate::coordinator::router::RouteError;
use crate::util::json::{self, Json};

/// Wire-format revision this build speaks (both directions).
pub const WIRE_VERSION: u64 = 1;
/// Format tag carried by the handshake frames.
pub const WIRE_FORMAT: &str = "topkima-shard-wire";
/// Upper bound on one frame's JSON payload — a corrupt length prefix
/// must not make the reader allocate unbounded memory.
const MAX_FRAME_BYTES: usize = 64 << 20;

/// Typed wire-protocol errors.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum WireError {
    /// Pipe-level I/O failure (worker died, EPIPE, ...).
    Io(String),
    /// Malformed framing or JSON (bad length prefix, truncated frame).
    Frame(String),
    /// Handshake declared a format/version this build does not speak.
    Version { got: String },
    /// Structurally valid frame that violates the protocol (unknown
    /// kind, unexpected frame for the current state, bad field).
    Protocol(String),
}

impl fmt::Display for WireError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WireError::Io(msg) => write!(f, "wire i/o: {msg}"),
            WireError::Frame(msg) => write!(f, "wire framing: {msg}"),
            WireError::Version { got } => write!(
                f,
                "wire version skew: peer speaks {got}, this build speaks \
                 {WIRE_FORMAT} v{WIRE_VERSION}"
            ),
            WireError::Protocol(msg) => write!(f, "wire protocol: {msg}"),
        }
    }
}

impl std::error::Error for WireError {}

fn proto(msg: impl fmt::Display) -> WireError {
    WireError::Protocol(msg.to_string())
}

/// Successful per-request result inside a [`Frame::Reply`].
#[derive(Clone, Debug, PartialEq)]
pub struct ReplyOk {
    pub output: Vec<f32>,
    pub latency_us: f64,
    pub batch_size: usize,
}

/// Failed per-request result inside a [`Frame::Reply`]. The front
/// reacts identically to both (drop the waiter so the caller's `recv`
/// fails immediately), but the distinction survives the wire for
/// diagnostics.
#[derive(Clone, Debug, PartialEq)]
pub enum ReplyError {
    /// Admission rejection (unknown stream / full queue), typed.
    Route(RouteError),
    /// The executor failed (or short-answered) the whole batch.
    Batch(String),
}

impl ReplyError {
    fn to_json(&self) -> Json {
        match self {
            ReplyError::Route(e) => e.to_json(),
            ReplyError::Batch(msg) => Json::obj(vec![
                ("kind", Json::Str("batch_failed".to_string())),
                ("msg", Json::Str(msg.clone())),
            ]),
        }
    }

    fn from_json(v: &Json) -> Result<ReplyError, String> {
        if v.get("kind").as_str() == Some("batch_failed") {
            let obj = v.as_obj().ok_or("error must be an object")?;
            let mut msg = None;
            for (key, value) in obj {
                match key.as_str() {
                    "kind" => {}
                    "msg" => {
                        msg = Some(
                            value.as_str().ok_or("msg must be a string")?,
                        )
                    }
                    other => {
                        return Err(format!(
                            "unknown batch_failed field '{other}'"
                        ))
                    }
                }
            }
            return Ok(ReplyError::Batch(
                msg.ok_or("batch_failed needs msg")?.to_string(),
            ));
        }
        RouteError::from_json(v).map(ReplyError::Route)
    }
}

/// One request travelling inside a [`Frame::Donate`] batch.
#[derive(Clone, Debug)]
pub struct DonatedRequest {
    pub id: RequestId,
    pub input: Arc<InputData>,
}

/// One frame of the fleet↔shard wire protocol. (Not `Clone`: the
/// metrics snapshot carries a full [`Metrics`] record, which is
/// move-only by design — a shard's accounting has exactly one owner.)
#[derive(Debug)]
pub enum Frame {
    /// Membership dial-in (first frame on a TCP member socket,
    /// worker → front). Carries the worker's OS pid so the front can
    /// report `worker_pid` for sockets the way the process transport
    /// does for children.
    Join { pid: u32 },
    /// Handshake + worker configuration (first frame, front → worker).
    Init {
        shard: usize,
        shards: usize,
        /// Force the synthetic executor (serve-fleet's load generator)
        /// instead of the auto artifact/synthetic choice.
        synthetic: bool,
        /// The full `StackConfig` as JSON — the worker rebuilds the
        /// pipeline from it, so front and worker can never disagree on
        /// stream policies.
        config: Json,
    },
    /// Handshake ack (first frame, worker → front).
    Ready { shard: usize },
    Submit {
        id: RequestId,
        family: String,
        k: usize,
        /// Front-side send instant, µs since the UNIX epoch (0 when the
        /// front's clock is unusable). `Instant`s cannot cross the
        /// process boundary, but front and worker share one host clock,
        /// so the worker back-dates the request's enqueue instant by
        /// the observed transit time — reported latencies then cover
        /// the pipe like the local transport's cover the channel.
        t_unix_us: u64,
        input: Arc<InputData>,
    },
    Reply {
        id: RequestId,
        result: Result<ReplyOk, ReplyError>,
    },
    Poke,
    Donate {
        family: String,
        k: usize,
        bucket: usize,
        requests: Vec<DonatedRequest>,
    },
    Steal,
    /// Periodic liveness beacon (worker → front, membership layer).
    Heartbeat { shard: usize },
    /// Voluntary departure: the worker asks to be evicted from routing,
    /// then drains and snapshots (worker → front, membership layer).
    Leave { shard: usize },
    MetricsSnapshot {
        /// Per-stream metrics executed on this shard.
        streams: Vec<(String, usize, Metrics)>,
        rejected: u64,
        stolen: u64,
        donated: u64,
    },
    Shutdown,
    Fatal { msg: String },
}

impl Frame {
    /// The frame's `kind` tag (diagnostics).
    pub fn kind(&self) -> &'static str {
        match self {
            Frame::Join { .. } => "join",
            Frame::Init { .. } => "init",
            Frame::Ready { .. } => "ready",
            Frame::Submit { .. } => "submit",
            Frame::Reply { .. } => "reply",
            Frame::Poke => "poke",
            Frame::Donate { .. } => "donate",
            Frame::Steal => "steal",
            Frame::Heartbeat { .. } => "heartbeat",
            Frame::Leave { .. } => "leave",
            Frame::MetricsSnapshot { .. } => "metrics_snapshot",
            Frame::Shutdown => "shutdown",
            Frame::Fatal { .. } => "fatal",
        }
    }

    pub fn to_json(&self) -> Json {
        let kind = |k: &str| ("kind", Json::Str(k.to_string()));
        match self {
            Frame::Join { pid } => Json::obj(vec![
                kind("join"),
                ("format", Json::Str(WIRE_FORMAT.to_string())),
                ("version", Json::Num(WIRE_VERSION as f64)),
                ("pid", Json::Num(*pid as f64)),
            ]),
            Frame::Init { shard, shards, synthetic, config } => {
                Json::obj(vec![
                    kind("init"),
                    ("format", Json::Str(WIRE_FORMAT.to_string())),
                    ("version", Json::Num(WIRE_VERSION as f64)),
                    ("shard", Json::Num(*shard as f64)),
                    ("shards", Json::Num(*shards as f64)),
                    ("synthetic", Json::Bool(*synthetic)),
                    ("config", config.clone()),
                ])
            }
            Frame::Ready { shard } => Json::obj(vec![
                kind("ready"),
                ("format", Json::Str(WIRE_FORMAT.to_string())),
                ("version", Json::Num(WIRE_VERSION as f64)),
                ("shard", Json::Num(*shard as f64)),
            ]),
            Frame::Submit { id, family, k, t_unix_us, input } => {
                Json::obj(vec![
                    kind("submit"),
                    ("id", Json::Num(*id as f64)),
                    ("family", Json::Str(family.clone())),
                    ("k", Json::Num(*k as f64)),
                    ("t_unix_us", Json::Num(*t_unix_us as f64)),
                    ("input", input.to_json()),
                ])
            }
            Frame::Reply { id, result } => {
                let mut fields = vec![kind("reply"), ("id", Json::Num(*id as f64))];
                match result {
                    Ok(ok) => {
                        fields.push((
                            "output",
                            // from_f32: a masked -inf logit (or a NaN
                            // from a misbehaving model) must fail at
                            // most its own value, never the frame
                            Json::Arr(
                                ok.output
                                    .iter()
                                    .map(|&x| Json::from_f32(x))
                                    .collect(),
                            ),
                        ));
                        fields.push(("latency_us", Json::Num(ok.latency_us)));
                        fields.push((
                            "batch_size",
                            Json::Num(ok.batch_size as f64),
                        ));
                    }
                    Err(e) => fields.push(("error", e.to_json())),
                }
                Json::obj(fields)
            }
            Frame::Poke => Json::obj(vec![kind("poke")]),
            Frame::Donate { family, k, bucket, requests } => Json::obj(vec![
                kind("donate"),
                ("family", Json::Str(family.clone())),
                ("k", Json::Num(*k as f64)),
                ("bucket", Json::Num(*bucket as f64)),
                (
                    "requests",
                    Json::Arr(
                        requests
                            .iter()
                            .map(|r| {
                                Json::obj(vec![
                                    ("id", Json::Num(r.id as f64)),
                                    ("input", r.input.to_json()),
                                ])
                            })
                            .collect(),
                    ),
                ),
            ]),
            Frame::Steal => Json::obj(vec![kind("steal")]),
            Frame::Heartbeat { shard } => Json::obj(vec![
                kind("heartbeat"),
                ("shard", Json::Num(*shard as f64)),
            ]),
            Frame::Leave { shard } => Json::obj(vec![
                kind("leave"),
                ("shard", Json::Num(*shard as f64)),
            ]),
            Frame::MetricsSnapshot { streams, rejected, stolen, donated } => {
                Json::obj(vec![
                    kind("metrics_snapshot"),
                    (
                        "streams",
                        Json::Arr(
                            streams
                                .iter()
                                .map(|(family, k, m)| {
                                    Json::obj(vec![
                                        (
                                            "family",
                                            Json::Str(family.clone()),
                                        ),
                                        ("k", Json::Num(*k as f64)),
                                        ("metrics", m.to_json()),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("rejected", Json::Num(*rejected as f64)),
                    ("stolen", Json::Num(*stolen as f64)),
                    ("donated", Json::Num(*donated as f64)),
                ])
            }
            Frame::Shutdown => Json::obj(vec![kind("shutdown")]),
            Frame::Fatal { msg } => Json::obj(vec![
                kind("fatal"),
                ("msg", Json::Str(msg.clone())),
            ]),
        }
    }

    /// Parse one frame. Unknown kinds, unknown fields, missing fields,
    /// and handshake version skew are all loud errors.
    pub fn from_json(v: &Json) -> Result<Frame, WireError> {
        let obj = v
            .as_obj()
            .ok_or_else(|| proto("frame must be a JSON object"))?;
        let kind = v
            .get("kind")
            .as_str()
            .ok_or_else(|| proto("frame needs a string 'kind'"))?;
        let int = |x: &Json, field: &str| -> Result<u64, WireError> {
            x.as_u64().ok_or_else(|| {
                proto(format!("{field} must be a non-negative integer"))
            })
        };
        // handshake frames get the version gate before field checks, so
        // a future revision that renames fields still reports "skew",
        // not "unknown field"
        if matches!(kind, "init" | "ready" | "join") {
            let format = v.get("format").as_str().unwrap_or("?");
            let version = v.get("version").as_f64().unwrap_or(-1.0);
            if format != WIRE_FORMAT || version != WIRE_VERSION as f64 {
                return Err(WireError::Version {
                    got: format!("{format} v{version}"),
                });
            }
        }
        match kind {
            "join" => {
                let mut pid = None;
                for (key, value) in obj {
                    match key.as_str() {
                        "kind" | "format" | "version" => {}
                        "pid" => pid = Some(int(value, "pid")? as u32),
                        other => {
                            return Err(proto(format!(
                                "unknown join field '{other}'"
                            )))
                        }
                    }
                }
                Ok(Frame::Join {
                    pid: pid.ok_or_else(|| proto("join needs pid"))?,
                })
            }
            "init" => {
                let (mut shard, mut shards, mut synthetic, mut config) =
                    (None, None, None, None);
                for (key, value) in obj {
                    match key.as_str() {
                        "kind" | "format" | "version" => {}
                        "shard" => {
                            shard = Some(int(value, "shard")? as usize)
                        }
                        "shards" => {
                            shards = Some(int(value, "shards")? as usize)
                        }
                        "synthetic" => {
                            synthetic = Some(value.as_bool().ok_or_else(
                                || proto("synthetic must be a boolean"),
                            )?)
                        }
                        "config" => config = Some(value.clone()),
                        other => {
                            return Err(proto(format!(
                                "unknown init field '{other}'"
                            )))
                        }
                    }
                }
                match (shard, shards, synthetic, config) {
                    (Some(shard), Some(shards), Some(synthetic), Some(config)) => {
                        Ok(Frame::Init { shard, shards, synthetic, config })
                    }
                    _ => Err(proto(
                        "init needs shard, shards, synthetic, config",
                    )),
                }
            }
            "ready" => {
                let mut shard = None;
                for (key, value) in obj {
                    match key.as_str() {
                        "kind" | "format" | "version" => {}
                        "shard" => {
                            shard = Some(int(value, "shard")? as usize)
                        }
                        other => {
                            return Err(proto(format!(
                                "unknown ready field '{other}'"
                            )))
                        }
                    }
                }
                Ok(Frame::Ready {
                    shard: shard.ok_or_else(|| proto("ready needs shard"))?,
                })
            }
            "submit" => {
                let (mut id, mut family, mut k, mut input) =
                    (None, None, None, None);
                let mut t_unix_us = None;
                for (key, value) in obj {
                    match key.as_str() {
                        "kind" => {}
                        "id" => id = Some(int(value, "id")?),
                        "family" => {
                            family = Some(
                                value
                                    .as_str()
                                    .ok_or_else(|| {
                                        proto("family must be a string")
                                    })?
                                    .to_string(),
                            )
                        }
                        "k" => k = Some(int(value, "k")? as usize),
                        "t_unix_us" => {
                            t_unix_us = Some(int(value, "t_unix_us")?)
                        }
                        "input" => {
                            input = Some(
                                InputData::from_json(value).map_err(proto)?,
                            )
                        }
                        other => {
                            return Err(proto(format!(
                                "unknown submit field '{other}'"
                            )))
                        }
                    }
                }
                match (id, family, k, t_unix_us, input) {
                    (
                        Some(id),
                        Some(family),
                        Some(k),
                        Some(t_unix_us),
                        Some(input),
                    ) => Ok(Frame::Submit {
                        id,
                        family,
                        k,
                        t_unix_us,
                        input: Arc::new(input),
                    }),
                    _ => Err(proto(
                        "submit needs id, family, k, t_unix_us, input",
                    )),
                }
            }
            "reply" => {
                let mut id = None;
                let (mut output, mut latency_us, mut batch_size, mut error) =
                    (None, None, None, None);
                for (key, value) in obj {
                    match key.as_str() {
                        "kind" => {}
                        "id" => id = Some(int(value, "id")?),
                        "output" => {
                            output = Some(
                                value
                                    .as_arr()
                                    .ok_or_else(|| {
                                        proto("output must be an array")
                                    })?
                                    .iter()
                                    .map(|x| {
                                        x.as_f32().ok_or_else(|| {
                                            proto(
                                                "output must be numbers \
                                                 (or the NaN/inf \
                                                 encodings)",
                                            )
                                        })
                                    })
                                    .collect::<Result<Vec<f32>, _>>()?,
                            )
                        }
                        "latency_us" => {
                            latency_us =
                                Some(value.as_f64().ok_or_else(|| {
                                    proto("latency_us must be a number")
                                })?)
                        }
                        "batch_size" => {
                            batch_size =
                                Some(int(value, "batch_size")? as usize)
                        }
                        "error" => {
                            error = Some(
                                ReplyError::from_json(value).map_err(proto)?,
                            )
                        }
                        other => {
                            return Err(proto(format!(
                                "unknown reply field '{other}'"
                            )))
                        }
                    }
                }
                let id = id.ok_or_else(|| proto("reply needs id"))?;
                let result = match (output, error) {
                    (Some(output), None) => Ok(ReplyOk {
                        output,
                        latency_us: latency_us.ok_or_else(|| {
                            proto("reply needs latency_us")
                        })?,
                        batch_size: batch_size.ok_or_else(|| {
                            proto("reply needs batch_size")
                        })?,
                    }),
                    (None, Some(error)) => Err(error),
                    _ => {
                        return Err(proto(
                            "reply needs exactly one of output / error",
                        ))
                    }
                };
                Ok(Frame::Reply { id, result })
            }
            "poke" => {
                only_kind(obj, "poke")?;
                Ok(Frame::Poke)
            }
            "donate" => {
                let (mut family, mut k, mut bucket, mut requests) =
                    (None, None, None, None);
                for (key, value) in obj {
                    match key.as_str() {
                        "kind" => {}
                        "family" => {
                            family = Some(
                                value
                                    .as_str()
                                    .ok_or_else(|| {
                                        proto("family must be a string")
                                    })?
                                    .to_string(),
                            )
                        }
                        "k" => k = Some(int(value, "k")? as usize),
                        "bucket" => {
                            bucket = Some(int(value, "bucket")? as usize)
                        }
                        "requests" => {
                            requests = Some(
                                value
                                    .as_arr()
                                    .ok_or_else(|| {
                                        proto("requests must be an array")
                                    })?
                                    .iter()
                                    .map(|r| {
                                        // nested objects are as strict
                                        // as frames: unknown fields are
                                        // skew, not noise
                                        let entry =
                                            r.as_obj().ok_or_else(|| {
                                                proto(
                                                    "donated request must \
                                                     be an object",
                                                )
                                            })?;
                                        let (mut id, mut input) =
                                            (None, None);
                                        for (key, value) in entry {
                                            match key.as_str() {
                                                "id" => {
                                                    id = Some(int(
                                                        value, "id",
                                                    )?)
                                                }
                                                "input" => {
                                                    input = Some(
                                                        InputData::from_json(
                                                            value,
                                                        )
                                                        .map_err(proto)?,
                                                    )
                                                }
                                                other => {
                                                    return Err(proto(
                                                        format!(
                                                        "unknown donated-\
                                                         request field \
                                                         '{other}'"
                                                    ),
                                                    ))
                                                }
                                            }
                                        }
                                        match (id, input) {
                                            (Some(id), Some(input)) => {
                                                Ok(DonatedRequest {
                                                    id,
                                                    input: Arc::new(input),
                                                })
                                            }
                                            _ => Err(proto(
                                                "donated request needs id, \
                                                 input",
                                            )),
                                        }
                                    })
                                    .collect::<Result<Vec<_>, WireError>>(
                                    )?,
                            )
                        }
                        other => {
                            return Err(proto(format!(
                                "unknown donate field '{other}'"
                            )))
                        }
                    }
                }
                match (family, k, bucket, requests) {
                    (Some(family), Some(k), Some(bucket), Some(requests)) => {
                        Ok(Frame::Donate { family, k, bucket, requests })
                    }
                    _ => Err(proto(
                        "donate needs family, k, bucket, requests",
                    )),
                }
            }
            "steal" => {
                only_kind(obj, "steal")?;
                Ok(Frame::Steal)
            }
            "heartbeat" => {
                Ok(Frame::Heartbeat { shard: only_shard(obj, kind)? })
            }
            "leave" => Ok(Frame::Leave { shard: only_shard(obj, kind)? }),
            "metrics_snapshot" => {
                let mut streams = None;
                let (mut rejected, mut stolen, mut donated) =
                    (None, None, None);
                for (key, value) in obj {
                    match key.as_str() {
                        "kind" => {}
                        "streams" => {
                            streams = Some(
                                value
                                    .as_arr()
                                    .ok_or_else(|| {
                                        proto("streams must be an array")
                                    })?
                                    .iter()
                                    .map(|s| {
                                        let entry =
                                            s.as_obj().ok_or_else(|| {
                                                proto(
                                                    "stream entry must be \
                                                     an object",
                                                )
                                            })?;
                                        let (
                                            mut family,
                                            mut k,
                                            mut metrics,
                                        ) = (None, None, None);
                                        for (key, value) in entry {
                                            match key.as_str() {
                                                "family" => {
                                                    family = Some(
                                                        value
                                                            .as_str()
                                                            .ok_or_else(
                                                                || proto(
                                                                "family must \
                                                                 be a string",
                                                            ),
                                                            )?
                                                            .to_string(),
                                                    )
                                                }
                                                "k" => {
                                                    k = Some(int(
                                                        value, "k",
                                                    )?
                                                        as usize)
                                                }
                                                "metrics" => {
                                                    metrics = Some(
                                                        Metrics::from_json(
                                                            value,
                                                        )
                                                        .map_err(proto)?,
                                                    )
                                                }
                                                other => {
                                                    return Err(proto(
                                                        format!(
                                                        "unknown stream-\
                                                         entry field \
                                                         '{other}'"
                                                    ),
                                                    ))
                                                }
                                            }
                                        }
                                        match (family, k, metrics) {
                                            (
                                                Some(family),
                                                Some(k),
                                                Some(metrics),
                                            ) => Ok((family, k, metrics)),
                                            _ => Err(proto(
                                                "stream entry needs family, \
                                                 k, metrics",
                                            )),
                                        }
                                    })
                                    .collect::<Result<Vec<_>, WireError>>(
                                    )?,
                            )
                        }
                        "rejected" => {
                            rejected = Some(int(value, "rejected")?)
                        }
                        "stolen" => stolen = Some(int(value, "stolen")?),
                        "donated" => donated = Some(int(value, "donated")?),
                        other => {
                            return Err(proto(format!(
                                "unknown metrics_snapshot field '{other}'"
                            )))
                        }
                    }
                }
                match (streams, rejected, stolen, donated) {
                    (
                        Some(streams),
                        Some(rejected),
                        Some(stolen),
                        Some(donated),
                    ) => Ok(Frame::MetricsSnapshot {
                        streams,
                        rejected,
                        stolen,
                        donated,
                    }),
                    _ => Err(proto(
                        "metrics_snapshot needs streams, rejected, stolen, \
                         donated",
                    )),
                }
            }
            "shutdown" => {
                only_kind(obj, "shutdown")?;
                Ok(Frame::Shutdown)
            }
            "fatal" => {
                let mut msg = None;
                for (key, value) in obj {
                    match key.as_str() {
                        "kind" => {}
                        "msg" => {
                            msg = Some(
                                value
                                    .as_str()
                                    .ok_or_else(|| {
                                        proto("msg must be a string")
                                    })?
                                    .to_string(),
                            )
                        }
                        other => {
                            return Err(proto(format!(
                                "unknown fatal field '{other}'"
                            )))
                        }
                    }
                }
                Ok(Frame::Fatal {
                    msg: msg.ok_or_else(|| proto("fatal needs msg"))?,
                })
            }
            other => Err(proto(format!("unknown frame kind '{other}'"))),
        }
    }
}

/// Decode a frame whose only payload is a `shard` index (the membership
/// beacons `heartbeat` / `leave`). Unknown fields are skew, as always.
fn only_shard(
    obj: &std::collections::BTreeMap<String, Json>,
    kind: &str,
) -> Result<usize, WireError> {
    let mut shard = None;
    for (key, value) in obj {
        match key.as_str() {
            "kind" => {}
            "shard" => {
                shard = Some(
                    value.as_u64().ok_or_else(|| {
                        proto("shard must be a non-negative integer")
                    })? as usize,
                )
            }
            other => {
                return Err(proto(format!("unknown {kind} field '{other}'")))
            }
        }
    }
    shard.ok_or_else(|| proto(format!("{kind} needs shard")))
}

/// Reject any field except `kind` (payload-free frames).
fn only_kind(
    obj: &std::collections::BTreeMap<String, Json>,
    kind: &str,
) -> Result<(), WireError> {
    for key in obj.keys() {
        if key != "kind" {
            return Err(proto(format!("unknown {kind} field '{key}'")));
        }
    }
    Ok(())
}

/// Write one length-prefixed frame and flush it (frames are the unit of
/// progress on the pipe; buffering across frames would deadlock a
/// request/reply exchange).
pub fn write_frame<W: Write>(w: &mut W, frame: &Frame) -> Result<(), WireError> {
    let text = json::to_string(&frame.to_json());
    write_frame_io(w, &text).map_err(|e| WireError::Io(e.to_string()))
}

fn write_frame_io<W: Write>(w: &mut W, text: &str) -> std::io::Result<()> {
    write!(w, "{} ", text.len())?;
    w.write_all(text.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

/// Read one frame. `Ok(None)` is a clean EOF *between* frames; EOF
/// inside a frame (killed peer) is a loud [`WireError::Frame`].
pub fn read_frame<R: BufRead>(r: &mut R) -> Result<Option<Frame>, WireError> {
    // length prefix: ASCII decimal, terminated by one space
    let mut len: usize = 0;
    let mut any = false;
    loop {
        let mut byte = [0u8; 1];
        let n = r
            .read(&mut byte)
            .map_err(|e| WireError::Io(e.to_string()))?;
        if n == 0 {
            return if any {
                Err(WireError::Frame("eof inside length prefix".to_string()))
            } else {
                Ok(None)
            };
        }
        match byte[0] {
            b'0'..=b'9' => {
                len = len
                    .saturating_mul(10)
                    .saturating_add((byte[0] - b'0') as usize);
                if len > MAX_FRAME_BYTES {
                    return Err(WireError::Frame(format!(
                        "frame length {len} exceeds the {MAX_FRAME_BYTES} \
                         byte bound"
                    )));
                }
                any = true;
            }
            b' ' if any => break,
            other => {
                return Err(WireError::Frame(format!(
                    "bad byte 0x{other:02x} in length prefix"
                )))
            }
        }
    }
    let mut buf = vec![0u8; len];
    r.read_exact(&mut buf).map_err(|e| {
        WireError::Frame(format!("truncated frame ({len} bytes expected): {e}"))
    })?;
    let mut nl = [0u8; 1];
    r.read_exact(&mut nl)
        .map_err(|e| WireError::Frame(format!("missing frame newline: {e}")))?;
    if nl[0] != b'\n' {
        return Err(WireError::Frame(format!(
            "frame length prefix disagrees with payload (got 0x{:02x} where \
             the newline belongs)",
            nl[0]
        )));
    }
    let text = std::str::from_utf8(&buf)
        .map_err(|e| WireError::Frame(format!("frame is not utf-8: {e}")))?;
    let v = Json::parse(text)
        .map_err(|e| WireError::Frame(format!("frame json: {e}")))?;
    Frame::from_json(&v).map(Some)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn roundtrip(frame: &Frame) -> Frame {
        let mut buf = Vec::new();
        write_frame(&mut buf, frame).unwrap();
        let mut cur = Cursor::new(buf);
        let back = read_frame(&mut cur).unwrap().expect("one frame");
        // stream exhausted cleanly afterwards
        assert_eq!(read_frame(&mut cur).unwrap().map(|f| f.to_json()), None);
        back
    }

    #[test]
    fn every_frame_kind_roundtrips_through_the_pipe() {
        // an event-free metrics record keeps window_us = idle_us = 0, so
        // the snapshot frame re-serializes bit-identically after the
        // parse-time re-anchor; recorded samples (whose idle_us grows
        // with wall time between serialize and re-serialize) are covered
        // with tolerance by the metrics.rs roundtrip tests
        let metrics = Metrics::default();
        let frames = vec![
            Frame::Join { pid: 4242 },
            Frame::Heartbeat { shard: 2 },
            Frame::Leave { shard: 2 },
            Frame::Init {
                shard: 1,
                shards: 4,
                synthetic: true,
                config: Json::obj(vec![("k", Json::Num(5.0))]),
            },
            Frame::Ready { shard: 1 },
            Frame::Submit {
                id: 42,
                family: "bert".to_string(),
                k: 5,
                t_unix_us: 1_722_000_000_000_000,
                input: Arc::new(InputData::I32(vec![1, 2, 3])),
            },
            Frame::Reply {
                id: 42,
                result: Ok(ReplyOk {
                    // -inf: a masked logit must survive the pipe
                    output: vec![0.5, -1.5, f32::NEG_INFINITY],
                    latency_us: 812.25,
                    batch_size: 4,
                }),
            },
            Frame::Reply {
                id: 7,
                result: Err(ReplyError::Route(RouteError::QueueFull {
                    stream: (Arc::from("bert"), 5),
                    depth: 9,
                })),
            },
            Frame::Reply {
                id: 8,
                result: Err(ReplyError::Batch("device fault".to_string())),
            },
            Frame::Poke,
            Frame::Donate {
                family: "vit".to_string(),
                k: 2,
                bucket: 4,
                requests: vec![DonatedRequest {
                    id: 3,
                    input: Arc::new(InputData::F32(vec![0.25])),
                }],
            },
            Frame::Steal,
            Frame::MetricsSnapshot {
                streams: vec![("bert".to_string(), 5, metrics)],
                rejected: 2,
                stolen: 0,
                donated: 0,
            },
            Frame::Shutdown,
            Frame::Fatal { msg: "boom".to_string() },
        ];
        for frame in &frames {
            let back = roundtrip(frame);
            assert_eq!(back.kind(), frame.kind());
            // JSON-level identity (Frame holds Metrics, which has no
            // PartialEq; the wire form is the contract anyway). The
            // snapshot's window is zero-width here, so even the
            // re-anchored metrics serialize identically.
            assert_eq!(back.to_json(), frame.to_json(), "{}", frame.kind());
        }
    }

    #[test]
    fn several_frames_stream_in_order() {
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Poke).unwrap();
        write_frame(&mut buf, &Frame::Steal).unwrap();
        write_frame(&mut buf, &Frame::Shutdown).unwrap();
        let mut cur = Cursor::new(buf);
        let kinds: Vec<&str> = std::iter::from_fn(|| {
            read_frame(&mut cur).unwrap().map(|f| f.kind())
        })
        .collect();
        assert_eq!(kinds, vec!["poke", "steal", "shutdown"]);
    }

    #[test]
    fn version_skew_is_rejected_loudly() {
        let future = Json::obj(vec![
            ("kind", Json::Str("ready".to_string())),
            ("format", Json::Str(WIRE_FORMAT.to_string())),
            ("version", Json::Num(99.0)),
            ("shard", Json::Num(0.0)),
        ]);
        assert!(matches!(
            Frame::from_json(&future),
            Err(WireError::Version { .. })
        ));
        let alien = Json::obj(vec![
            ("kind", Json::Str("init".to_string())),
            ("format", Json::Str("other-proto".to_string())),
            ("version", Json::Num(1.0)),
        ]);
        assert!(matches!(
            Frame::from_json(&alien),
            Err(WireError::Version { .. })
        ));
        // the membership dial-in is version-gated like init/ready: a
        // worker from a future build is told "skew", not "bad field"
        let join = Json::obj(vec![
            ("kind", Json::Str("join".to_string())),
            ("format", Json::Str(WIRE_FORMAT.to_string())),
            ("version", Json::Num(2.0)),
            ("pid", Json::Num(1.0)),
        ]);
        assert!(matches!(
            Frame::from_json(&join),
            Err(WireError::Version { .. })
        ));
        // version skew reports as skew even when fields also changed
        let renamed = Json::obj(vec![
            ("kind", Json::Str("ready".to_string())),
            ("format", Json::Str(WIRE_FORMAT.to_string())),
            ("version", Json::Num(2.0)),
            ("shard_id", Json::Num(0.0)),
        ]);
        assert!(matches!(
            Frame::from_json(&renamed),
            Err(WireError::Version { .. })
        ));
    }

    #[test]
    fn unknown_kinds_and_fields_are_rejected() {
        let unknown = Json::obj(vec![(
            "kind",
            Json::Str("teleport".to_string()),
        )]);
        match Frame::from_json(&unknown) {
            Err(WireError::Protocol(msg)) => {
                assert!(msg.contains("teleport"), "{msg}")
            }
            other => panic!("expected protocol error, got {other:?}"),
        }
        let extra = Json::obj(vec![
            ("kind", Json::Str("poke".to_string())),
            ("urgency", Json::Num(9.0)),
        ]);
        match Frame::from_json(&extra) {
            Err(WireError::Protocol(msg)) => {
                assert!(msg.contains("urgency"), "{msg}")
            }
            other => panic!("expected protocol error, got {other:?}"),
        }
        let beat = Json::obj(vec![
            ("kind", Json::Str("heartbeat".to_string())),
            ("shard", Json::Num(0.0)),
            ("rtt_us", Json::Num(9.0)),
        ]);
        match Frame::from_json(&beat) {
            Err(WireError::Protocol(msg)) => {
                assert!(msg.contains("rtt_us"), "{msg}")
            }
            other => panic!("expected protocol error, got {other:?}"),
        }
        // nested objects are strict too: a stream entry with an extra
        // field is skew, not noise
        let nested = Json::parse(
            r#"{"kind":"metrics_snapshot","rejected":0,"stolen":0,
                "donated":0,"streams":[{"family":"bert","k":5,
                "metrics":{},"shard":1}]}"#,
        )
        .unwrap();
        match Frame::from_json(&nested) {
            Err(WireError::Protocol(msg)) => {
                assert!(msg.contains("shard"), "{msg}")
            }
            other => panic!("expected protocol error, got {other:?}"),
        }
        let nested = Json::parse(
            r#"{"kind":"donate","family":"bert","k":5,"bucket":2,
                "requests":[{"id":1,
                "input":{"dtype":"i32","data":[1]},"prio":2}]}"#,
        )
        .unwrap();
        match Frame::from_json(&nested) {
            Err(WireError::Protocol(msg)) => {
                assert!(msg.contains("prio"), "{msg}")
            }
            other => panic!("expected protocol error, got {other:?}"),
        }
        let both = Json::obj(vec![
            ("kind", Json::Str("reply".to_string())),
            ("id", Json::Num(1.0)),
            ("output", Json::Arr(vec![])),
            ("latency_us", Json::Num(1.0)),
            ("batch_size", Json::Num(1.0)),
            (
                "error",
                Json::obj(vec![
                    ("kind", Json::Str("batch_failed".to_string())),
                    ("msg", Json::Str("x".to_string())),
                ]),
            ),
        ]);
        assert!(Frame::from_json(&both).is_err());
    }

    #[test]
    fn framing_violations_are_loud() {
        // corrupt length prefix
        let mut cur = Cursor::new(b"xx {\"kind\":\"poke\"}\n".to_vec());
        assert!(matches!(
            read_frame(&mut cur),
            Err(WireError::Frame(_))
        ));
        // truncated payload (killed worker mid-frame)
        let mut buf = Vec::new();
        write_frame(&mut buf, &Frame::Shutdown).unwrap();
        buf.truncate(buf.len() - 4);
        let mut cur = Cursor::new(buf);
        assert!(matches!(
            read_frame(&mut cur),
            Err(WireError::Frame(_))
        ));
        // length prefix that lies about the payload length
        let mut cur = Cursor::new(b"3 {\"kind\":\"poke\"}\n".to_vec());
        assert!(read_frame(&mut cur).is_err());
        // eof inside the prefix
        let mut cur = Cursor::new(b"12".to_vec());
        assert!(matches!(
            read_frame(&mut cur),
            Err(WireError::Frame(_))
        ));
    }
}
