//! [`LocalTransport`]: the in-process shard wiring, extracted from the
//! pre-trait `Fleet::start_with` unchanged — shard threads, `mpsc`
//! channels, and (when stealing is enabled) the fleet-wide steal deque
//! plus peer-poke senders. This is the behavior-preserving half of the
//! transport redesign: batch composition, metrics accounting, and
//! deterministic replay are byte-identical to the channel-era fleet,
//! which the existing fleet tests and the ci.sh replay gate assert.

use std::sync::mpsc;
use std::sync::Arc;

use crate::coordinator::fleet::StealPolicy;
use crate::coordinator::request::{Request, Response};
use crate::coordinator::router::{RouteError, Router};
use crate::coordinator::shard::{
    start_shard, start_shard_with, ExecutorFactory, ShardHandle, ShardMsg,
    ShardReport, StealCtx, StealShared,
};

use super::ShardTransport;

/// In-process transport: one OS thread per shard, channel-delivered
/// requests, in-memory work-stealing.
pub struct LocalTransport {
    shards: Vec<ShardHandle>,
}

impl LocalTransport {
    /// Spawn one shard event loop per router/factory pair. When
    /// stealing is enabled (and there is more than one shard), every
    /// shard holds its peers' channel senders for donation pokes —
    /// which means the channels only disconnect after an explicit
    /// shutdown, so a stealing fleet must always be shut down, never
    /// leaked.
    pub(crate) fn spawn(
        routers: Vec<Router>,
        factories: Vec<ExecutorFactory>,
        mut steal: StealPolicy,
    ) -> LocalTransport {
        // lint:allow(panic-path): spawn-time invariant — both vecs come from the same fleet-config loop, and a mismatch is a construction bug, not a request-path condition
        assert_eq!(
            routers.len(),
            factories.len(),
            "one router per shard factory"
        );
        // `StackConfig::validate` rejects min_backlog = 0, but library
        // callers can build a StealPolicy directly; clamp here (where
        // the policy is consumed) so a donor always keeps at least one
        // batch instead of idling itself and re-stealing its own work.
        if steal.enabled {
            steal.min_backlog = steal.min_backlog.max(1);
        }
        let n = factories.len();
        let shards = if steal.enabled && n > 1 {
            let shared = Arc::new(StealShared::new(n));
            let channels: Vec<_> =
                (0..n).map(|_| mpsc::channel::<ShardMsg>()).collect();
            let peers: Vec<mpsc::Sender<ShardMsg>> =
                channels.iter().map(|(tx, _)| tx.clone()).collect();
            routers
                .into_iter()
                .zip(factories)
                .zip(channels)
                .enumerate()
                .map(|(i, ((router, factory), (tx, rx)))| {
                    let ctx = StealCtx::enabled(
                        i,
                        steal,
                        shared.clone(),
                        peers.clone(),
                    );
                    start_shard_with(router, factory, tx, rx, ctx)
                })
                .collect()
        } else {
            routers
                .into_iter()
                .zip(factories)
                .map(|(router, factory)| start_shard(router, factory))
                .collect()
        };
        LocalTransport { shards }
    }
}

impl ShardTransport for LocalTransport {
    fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn kind(&self) -> &'static str {
        "local"
    }

    fn submit(
        &mut self,
        shard: usize,
        req: Request,
    ) -> Result<mpsc::Receiver<Response>, RouteError> {
        let (tx, rx) = mpsc::channel();
        // A dead or unknown shard (panicked executor, early exit, a
        // router pointing past the shard list) is a typed rejection,
        // not a panic — shutdown will additionally report a dead shard
        // as a `ShardPanic`.
        let Some(handle) = self.shards.get(shard) else {
            return Err(RouteError::ShardDown((req.model, req.k)));
        };
        if let Err(mpsc::SendError(ShardMsg::Submit(req, _))) =
            handle.tx.send(ShardMsg::Submit(req, tx))
        {
            return Err(RouteError::ShardDown((req.model, req.k)));
        }
        Ok(rx)
    }

    fn shutdown(self: Box<Self>) -> Vec<Option<ShardReport>> {
        // Signal every shard before joining any, so they drain their
        // queues concurrently.
        for shard in &self.shards {
            let _ = shard.tx.send(ShardMsg::Shutdown);
        }
        self.shards
            .into_iter()
            .map(|shard| shard.handle.join().ok())
            .collect()
    }
}
