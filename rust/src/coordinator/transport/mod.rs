//! The fleet↔shard boundary, as an API: [`ShardTransport`].
//!
//! PRs 3–4 built the fleet as N shard event loops behind one front, but
//! the boundary between them was hard-wired to in-memory `mpsc`
//! channels and a shared steal deque — no amount of incremental work on
//! that wiring reaches cross-*process* (and later cross-host) serving.
//! This module turns the boundary into a trait the front programs
//! against:
//!
//! * [`local::LocalTransport`] — today's wiring, extracted verbatim:
//!   shard threads, channels, and the in-process work-stealing deque.
//!   Behavior-preserving: batch composition, metrics, and deterministic
//!   replay are byte-identical to the pre-trait fleet.
//! * [`proc::ProcessTransport`] — `topkima shard-worker` subprocesses
//!   speaking the versioned, length-prefixed JSONL protocol in
//!   [`wire`] over stdin/stdout. Same `Fleet` front, same per-stream
//!   guarantees; a dead worker surfaces as typed
//!   [`RouteError::ShardDown`] submissions and a `ShardPanic`-style
//!   shutdown error instead of a hang.
//! * [`tcp::TcpTransport`] — cross-host shards over length-prefixed
//!   JSONL sockets, speaking the *same* [`wire`] frames. Workers dial
//!   in (`topkima fleet-worker --connect`), register via the
//!   `join`/`init`/`ready` handshake, heartbeat, and can join or leave
//!   **under live load**: the [`membership`] layer re-hashes stream
//!   routing over the live member set and evicts hosts whose
//!   heartbeats stop.
//!
//! The trait is deliberately narrow — deliver one request to one shard,
//! tear everything down and collect the per-shard reports — because
//! that is the whole contract the front needs. Work-stealing stays a
//! transport concern: the local transport mediates it in-process; the
//! process and TCP transports mediate it at the front over the
//! `donate`/`steal`/`poke` frames ([`membership::StealHub`]). The
//! membership hooks (`membership_epoch`, `live_shards`, `drain_shard`)
//! have fixed-topology defaults so the local and process transports
//! keep their static shard sets unchanged.
//!
//! [`RouteError::ShardDown`]: crate::coordinator::RouteError::ShardDown
//! [`membership`]: crate::coordinator::membership
//! [`membership::StealHub`]: crate::coordinator::membership::StealHub

pub mod local;
pub mod proc;
pub mod tcp;
pub mod wire;

use std::sync::mpsc;

use super::request::{Request, Response};
use super::router::RouteError;
pub use super::shard::ShardReport;

pub use local::LocalTransport;
pub use proc::{run_shard_worker, ProcessOptions, ProcessTransport};
pub use tcp::{run_fleet_worker, TcpOptions, TcpPending, TcpTransport};
pub use wire::{Frame, WireError, WIRE_FORMAT, WIRE_VERSION};

/// How requests reach a shard and reports come back — the one interface
/// between the `Fleet` front and its shard event loops.
///
/// Implementations own the shards' lifecycle: the front never sees
/// threads, channels, pipes, or processes, only this contract:
///
/// * `submit` delivers one request to shard `shard` (the front already
///   resolved the stream→shard assignment via `shard_of`) and returns
///   the receiver its [`Response`] will arrive on. A shard that can no
///   longer accept work is a typed [`RouteError::ShardDown`], never a
///   panic; a request that is accepted but later fails has its reply
///   sender dropped, so the caller's `recv` fails promptly.
/// * `shutdown` drains every shard and returns one entry per shard:
///   `Some(report)` for a clean exit, `None` for a shard that panicked
///   or died (the front turns those into a `ShardPanic` error carrying
///   the healthy shards' partial metrics).
pub trait ShardTransport: Send {
    /// Number of shard slots this transport has ever created (dead and
    /// drained slots included — report vectors stay index-stable).
    fn shard_count(&self) -> usize;

    /// Stable identifier for logs and BENCH output
    /// ("local", "process", "tcp").
    fn kind(&self) -> &'static str;

    /// Deliver one request to `shard`; its reply arrives on the
    /// returned receiver.
    fn submit(
        &mut self,
        shard: usize,
        req: Request,
    ) -> Result<mpsc::Receiver<Response>, RouteError>;

    /// OS pid of the shard's worker process, when it has one (the
    /// process and TCP transports; `None` for in-process threads).
    fn worker_pid(&self, _shard: usize) -> Option<u32> {
        None
    }

    /// Routing epoch: bumps on every join/leave/eviction, so the front
    /// can rebuild its stream→shard table exactly when membership
    /// changed — the steady-state submit path probes this and nothing
    /// else. Fixed topologies (local, process) never bump: always 0.
    fn membership_epoch(&self) -> u64 {
        0
    }

    /// The routable shard slots, ascending. Only consulted when
    /// `membership_epoch` moved. Fixed topologies: every slot, always.
    fn live_shards(&self) -> Vec<usize> {
        (0..self.shard_count()).collect()
    }

    /// Gracefully drain one shard (scale-in under live load): stop
    /// routing to it, flush its in-flight batches, stash its report for
    /// `shutdown`. `false` when this transport cannot drain single
    /// shards (fixed topologies).
    fn drain_shard(&mut self, _shard: usize) -> bool {
        false
    }

    /// Tear down every shard and collect final reports, one per shard
    /// in index order; `None` marks a shard that panicked or died.
    fn shutdown(self: Box<Self>) -> Vec<Option<ShardReport>>;
}
