//! [`TcpTransport`]: a cross-host fleet whose shards *dial in*.
//!
//! Where the process transport spawns its workers and owns their pipes,
//! the TCP front binds a listener (`fleet.transport.listen`) and waits
//! for `topkima fleet-worker --connect HOST:PORT` processes to dial it.
//! Each accepted socket runs one member session: a `join` frame names
//! the worker (pid), the front allocates the next shard slot and ships
//! the full `StackConfig` in an `init` frame, and the worker answers
//! `ready` once its router and executor are built — from then on the
//! session speaks exactly the frames the process transport does, plus
//! the membership layer of DESIGN.md §16:
//!
//! * **Heartbeats** — workers beacon `heartbeat` frames at
//!   `fleet.transport.heartbeat_ms`; the front counts *any* inbound
//!   frame as liveness and a monitor thread evicts members silent for
//!   longer than `interval × miss_budget` (socket shut down, slot
//!   `Down`, epoch bumped — the fleet re-hashes and submits to the dead
//!   slot degrade to typed `ShardDown`).
//! * **Elastic membership** — workers may join after serving started
//!   (scale-out: the accept loop never stops until shutdown) or leave
//!   voluntarily (`leave` frame, scale-in): both bump the
//!   [`MemberTable`] epoch, and the fleet front re-hashes its
//!   stream→shard table over the live member set
//!   (`fleet::shard_of_live`). Slots are append-only, so a departed
//!   member's metrics report keeps its index.
//! * **Graceful drain** — `shutdown` (and front-initiated
//!   `drain_shard`) sends the shutdown frame, the worker flushes every
//!   queued batch, replies stream back, and the final
//!   `metrics_snapshot` is stashed per slot before the socket closes.
//! * **Work-stealing over the wire** — the same front-mediated
//!   `steal`/`donate` protocol as the process transport, through the
//!   shared [`StealHub`]; batch composition never changes, so
//!   deterministic replay stays byte-identical with stealing on.
//!
//! The worker half reuses the process worker's event loop
//! ([`run_worker_loop`]) with heartbeats enabled — batch formation is
//! the same `Router`/`Batcher` code on every transport, which is what
//! makes the three-way replay `cmp` in ci.sh meaningful.
//!
//! [`MemberTable`]: crate::coordinator::membership::MemberTable
//! [`StealHub`]: crate::coordinator::membership::StealHub
//! [`run_worker_loop`]: super::proc::run_worker_loop

use std::collections::{BTreeMap, HashMap};
use std::io::{BufReader, BufWriter};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::membership::{
    lock, mediate_donation, send_locked, HeartbeatConfig, MemberState,
    MemberTable, SlotHandle, StealHub,
};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{Request, RequestId, Response};
use crate::coordinator::router::{RouteError, Router, StreamKey};
use crate::coordinator::shard::ShardReport;
use crate::util::json::Json;

use super::proc::{
    fatal, run_worker_loop, spawn_frame_forwarder, unix_us, WorkerMsg,
    WorkerOpts,
};
use super::wire::{self, Frame, WireError};
use super::ShardTransport;

/// How long a dialing worker retries an unreachable front before giving
/// up (the front usually binds a beat after the workers launch).
const DIAL_RETRY_BUDGET: Duration = Duration::from_secs(10);

/// How long `shutdown` waits for draining members to deliver their
/// final snapshots before force-closing their sockets.
const SHUTDOWN_DRAIN_BUDGET: Duration = Duration::from_secs(60);

type TcpWriter = BufWriter<TcpStream>;

/// One dialed-in member: the shared waiter/writer/down handle every
/// transport keeps, plus a raw socket clone for forced teardown
/// (eviction and shutdown stragglers).
struct TcpSlot {
    handle: SlotHandle<TcpWriter>,
    sock: TcpStream,
}

/// State shared between the accept loop, the per-member session
/// threads, the heartbeat monitor, and the transport front.
struct Shared {
    members: MemberTable,
    hub: StealHub,
    /// Index-aligned with [`MemberTable`] slots; append-only. The lock
    /// is held across `members.join` + push so concurrent dials cannot
    /// interleave and misalign the two tables.
    slots: Mutex<Vec<TcpSlot>>,
    /// Final metrics snapshots, by slot, stashed as drains complete.
    reports: Mutex<HashMap<usize, ShardReport>>,
    sessions: Mutex<Vec<JoinHandle<()>>>,
    stopping: AtomicBool,
    config: Json,
    synthetic: bool,
}

/// Everything [`TcpPending::bind`] needs, resolved from
/// `StackConfig.fleet.transport` by the pipeline builder.
pub struct TcpOptions {
    /// Workers that must complete the handshake before
    /// [`TcpPending::into_transport`] returns (the config's
    /// `fleet.shards`; more may join later — that is the point).
    pub expect: usize,
    /// The full stack configuration, shipped verbatim in every member's
    /// `init` frame.
    pub config: Json,
    /// Force the synthetic executor in workers.
    pub synthetic: bool,
    /// The liveness contract enforced by the monitor thread.
    pub heartbeat: HeartbeatConfig,
}

/// A bound-but-not-yet-ready TCP front: the listener is accepting and
/// the address is known (so the caller can print the dial command), but
/// the expected workers have not all joined yet.
pub struct TcpPending {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    expect: usize,
    heartbeat: HeartbeatConfig,
}

impl TcpPending {
    /// Bind the listen address and start accepting worker dials. The
    /// error message always names the failed `bind` — ci.sh keys its
    /// sandbox SKIP off that word.
    pub fn bind(addr: &str, opts: TcpOptions) -> Result<TcpPending, WireError> {
        let listener = TcpListener::bind(addr)
            .map_err(|e| WireError::Io(format!("bind {addr}: {e}")))?;
        let local = listener
            .local_addr()
            .map_err(|e| WireError::Io(format!("bind {addr}: {e}")))?;
        let shared = Arc::new(Shared {
            members: MemberTable::new(),
            hub: StealHub::new(),
            slots: Mutex::new(Vec::new()),
            reports: Mutex::new(HashMap::new()),
            sessions: Mutex::new(Vec::new()),
            stopping: AtomicBool::new(false),
            config: opts.config,
            synthetic: opts.synthetic,
        });
        let accept = {
            let shared = shared.clone();
            std::thread::spawn(move || accept_loop(listener, shared))
        };
        Ok(TcpPending {
            addr: local,
            shared,
            accept: Some(accept),
            expect: opts.expect,
            heartbeat: opts.heartbeat,
        })
    }

    /// The bound address (resolves `:0` to the kernel-assigned port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Wait until `expect` workers are routable, then start the
    /// heartbeat monitor and hand over the live transport. On timeout
    /// the listener is torn down and the error names the dial command
    /// the missing workers should have run.
    pub fn into_transport(
        mut self,
        timeout: Duration,
    ) -> Result<TcpTransport, WireError> {
        let deadline = Instant::now() + timeout;
        while self.shared.members.live().len() < self.expect {
            if Instant::now() >= deadline {
                let ready = self.shared.members.live().len();
                stop_listening(&self.shared, self.addr, &mut self.accept);
                return Err(WireError::Io(format!(
                    "waited {:.1}s for {} fleet worker(s) to dial in \
                     ({ready} ready); start them with \
                     `topkima fleet-worker --connect {}`",
                    timeout.as_secs_f64(),
                    self.expect,
                    self.addr
                )));
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        let monitor = {
            let shared = self.shared.clone();
            let hb = self.heartbeat;
            std::thread::spawn(move || monitor_loop(shared, hb))
        };
        Ok(TcpTransport {
            shared: self.shared,
            addr: self.addr,
            accept: self.accept,
            monitor: Some(monitor),
        })
    }
}

/// Cross-host shard transport (see the module docs).
pub struct TcpTransport {
    shared: Arc<Shared>,
    addr: SocketAddr,
    accept: Option<JoinHandle<()>>,
    monitor: Option<JoinHandle<()>>,
}

impl ShardTransport for TcpTransport {
    fn shard_count(&self) -> usize {
        self.shared.members.total()
    }

    fn kind(&self) -> &'static str {
        "tcp"
    }

    fn submit(
        &mut self,
        shard: usize,
        req: Request,
    ) -> Result<mpsc::Receiver<Response>, RouteError> {
        let key: StreamKey = (req.model.clone(), req.k);
        // `NO_SHARD` (an emptied-out live set) and never-allocated slots
        // both land here: typed rejection, never a panic
        let Some(h) = lock(&self.shared.slots)
            .get(shard)
            .map(|s| s.handle.clone())
        else {
            return Err(RouteError::ShardDown(key));
        };
        if h.down.load(Ordering::Acquire) {
            return Err(RouteError::ShardDown(key));
        }
        let (tx, rx) = mpsc::channel();
        // insert before writing: the reply may race back before this
        // thread would regain the lock
        lock(&h.waiters).insert(req.id, tx);
        let frame = Frame::Submit {
            id: req.id,
            family: req.model.to_string(),
            k: req.k,
            t_unix_us: unix_us(),
            input: req.input,
        };
        let delivered = match send_locked(&h.writer, &frame) {
            Ok(true) => Ok(()),
            Ok(false) => {
                Err(WireError::Io("writer already closed".to_string()))
            }
            Err(e) => Err(e),
        };
        if let Err(e) = delivered {
            eprintln!("fleet worker {shard}: submit not delivered: {e}");
            h.down.store(true, Ordering::Release);
            lock(&h.waiters).remove(&req.id);
            return Err(RouteError::ShardDown(key));
        }
        // Close the race with the session's exit sweep (same protocol
        // as the process transport): the session stores `down` before
        // clearing waiters, so a false read here means our waiter either
        // survives or was just swept; a true read means it may have
        // landed after the sweep and must be removed by hand.
        if h.down.load(Ordering::Acquire) {
            lock(&h.waiters).remove(&req.id);
            return Err(RouteError::ShardDown(key));
        }
        Ok(rx)
    }

    fn worker_pid(&self, shard: usize) -> Option<u32> {
        self.shared.members.pid(shard)
    }

    fn membership_epoch(&self) -> u64 {
        self.shared.members.epoch()
    }

    fn live_shards(&self) -> Vec<usize> {
        self.shared.members.live()
    }

    fn drain_shard(&mut self, shard: usize) -> bool {
        if !self.shared.members.mark_draining(shard) {
            return false;
        }
        self.shared.hub.forget(shard);
        let h = lock(&self.shared.slots)
            .get(shard)
            .map(|s| s.handle.clone());
        match h {
            Some(h) => {
                if !matches!(
                    send_locked(&h.writer, &Frame::Shutdown),
                    Ok(true)
                ) {
                    member_gone(&self.shared, shard);
                }
            }
            None => member_gone(&self.shared, shard),
        }
        true
    }

    fn shutdown(mut self: Box<Self>) -> Vec<Option<ShardReport>> {
        let shared = self.shared.clone();
        // no new members from here on; the wake-dial unblocks `accept`
        stop_listening(&shared, self.addr, &mut self.accept);
        let total = shared.members.total();
        // signal every non-terminal member, so they drain concurrently
        for slot in 0..total {
            if matches!(
                shared.members.state(slot),
                None | Some(MemberState::Down | MemberState::Drained)
            ) {
                continue;
            }
            let h = lock(&shared.slots)
                .get(slot)
                .map(|s| s.handle.clone());
            if let Some(h) = h {
                if !matches!(
                    send_locked(&h.writer, &Frame::Shutdown),
                    Ok(true)
                ) {
                    member_gone(&shared, slot);
                }
            }
        }
        // wait for every slot to reach a terminal state (snapshot
        // stashed or socket gone), then force-close stragglers
        let deadline = Instant::now() + SHUTDOWN_DRAIN_BUDGET;
        loop {
            let pending: Vec<usize> = (0..total)
                .filter(|&slot| {
                    !matches!(
                        shared.members.state(slot),
                        None | Some(MemberState::Down | MemberState::Drained)
                    )
                })
                .collect();
            if pending.is_empty() {
                break;
            }
            if Instant::now() >= deadline {
                for slot in pending {
                    eprintln!(
                        "fleet front: shard {slot} did not drain within \
                         {}s; force-closing its socket",
                        SHUTDOWN_DRAIN_BUDGET.as_secs()
                    );
                    evict(&shared, slot);
                }
                break;
            }
            std::thread::sleep(Duration::from_millis(10));
        }
        // every session thread unblocks once its socket is closed
        {
            let slots = lock(&shared.slots);
            for s in slots.iter() {
                let _ = s.sock.shutdown(Shutdown::Both);
            }
        }
        if let Some(m) = self.monitor.take() {
            let _ = m.join();
        }
        let sessions = std::mem::take(&mut *lock(&shared.sessions));
        for h in sessions {
            let _ = h.join();
        }
        let mut reports = lock(&shared.reports);
        (0..total).map(|slot| reports.remove(&slot)).collect()
    }
}

/// Accept worker dials until `stopping`; one session thread per socket.
fn accept_loop(listener: TcpListener, shared: Arc<Shared>) {
    for conn in listener.incoming() {
        if shared.stopping.load(Ordering::Acquire) {
            return;
        }
        match conn {
            Ok(stream) => {
                let session_shared = shared.clone();
                let handle = std::thread::spawn(move || {
                    member_session(stream, session_shared)
                });
                lock(&shared.sessions).push(handle);
            }
            Err(e) => eprintln!("fleet front: accept failed: {e}"),
        }
    }
}

/// Unblock and join the accept loop: set the flag, then dial the
/// listener once so `incoming()` yields and observes it.
fn stop_listening(
    shared: &Shared,
    addr: SocketAddr,
    accept: &mut Option<JoinHandle<()>>,
) {
    shared.stopping.store(true, Ordering::Release);
    let _ = TcpStream::connect(addr);
    if let Some(h) = accept.take() {
        let _ = h.join();
    }
}

/// Sweep for members whose silence exhausted the heartbeat budget and
/// evict them. Ticks faster than it sweeps so shutdown never waits a
/// full (possibly huge) heartbeat interval for this thread to notice
/// `stopping`.
fn monitor_loop(shared: Arc<Shared>, hb: HeartbeatConfig) {
    let sweep = (hb.interval() / 2).max(Duration::from_millis(1));
    let tick = sweep.min(Duration::from_millis(50));
    let mut last_sweep = Instant::now();
    loop {
        std::thread::sleep(tick);
        if shared.stopping.load(Ordering::Acquire) {
            return;
        }
        if last_sweep.elapsed() < sweep {
            continue;
        }
        last_sweep = Instant::now();
        for slot in shared.members.overdue(hb.max_silence()) {
            eprintln!(
                "fleet front: shard {slot} silent past its heartbeat \
                 budget ({}ms × {}); evicting",
                hb.interval_ms, hb.miss_budget
            );
            evict(&shared, slot);
        }
    }
}

/// Forced teardown: close the member's socket (the session thread's
/// blocking read errors out promptly) and run the down sweep.
fn evict(shared: &Shared, slot: usize) {
    if let Some(s) = lock(&shared.slots).get(slot) {
        let _ = s.sock.shutdown(Shutdown::Both);
    }
    member_gone(shared, slot);
}

/// The member is gone (EOF, eviction, protocol error). Idempotent, and
/// ordered like the process reader's exit path: `down` stores before
/// the waiter sweep so `submit`'s double-check can never leak a waiter
/// onto a dead slot.
fn member_gone(shared: &Shared, slot: usize) {
    shared.members.mark_down(slot);
    shared.hub.forget(slot);
    let handle = lock(&shared.slots).get(slot).map(|s| s.handle.clone());
    if let Some(h) = handle {
        h.down.store(true, Ordering::Release);
        // dropping the senders fails every pending recv — no hangs
        lock(&h.waiters).clear();
        *lock(&h.writer) = None;
    }
}

/// One member's lifetime on the front: handshake, frame dispatch, exit
/// sweep. Runs on its own thread per accepted socket.
fn member_session(stream: TcpStream, shared: Arc<Shared>) {
    let _ = stream.set_nodelay(true);
    let peer = stream
        .peer_addr()
        .map(|a| a.to_string())
        .unwrap_or_else(|_| "<unknown>".to_string());
    let writer_half = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("fleet front: cloning socket for {peer}: {e}");
            return;
        }
    };
    let sock = match stream.try_clone() {
        Ok(s) => s,
        Err(e) => {
            eprintln!("fleet front: cloning socket for {peer}: {e}");
            return;
        }
    };
    let mut reader = BufReader::new(stream);

    // -- join handshake: no slot is allocated until the dialer proves
    // -- it speaks the protocol (a port probe costs nothing)
    let pid = match wire::read_frame(&mut reader) {
        Ok(Some(Frame::Join { pid })) => Some(pid),
        Ok(None) => return, // wake-dial or port scan: silently dropped
        Ok(Some(other)) => {
            let mut w = BufWriter::new(&writer_half);
            fatal(
                &mut w,
                &format!("expected join handshake, got '{}'", other.kind()),
            );
            return;
        }
        Err(e) => {
            eprintln!("fleet front: rejected dial from {peer}: {e}");
            return;
        }
    };
    let handle = SlotHandle {
        waiters: Arc::new(Mutex::new(HashMap::new())),
        writer: Arc::new(Mutex::new(Some(BufWriter::new(writer_half)))),
        down: Arc::new(AtomicBool::new(false)),
    };
    // one lock across both tables: concurrent dials must not interleave
    // the member-slot and socket-slot pushes
    let slot = {
        let mut slots = lock(&shared.slots);
        let slot = shared.members.join(pid);
        slots.push(TcpSlot { handle: handle.clone(), sock });
        slot
    };
    let init = Frame::Init {
        shard: slot,
        // the worker only range-checks its own index; an elastic
        // fleet's member count is the roster, not a fixed constant
        shards: slot + 1,
        synthetic: shared.synthetic,
        config: shared.config.clone(),
    };
    match send_locked(&handle.writer, &init) {
        Ok(true) => {}
        Ok(false) => {
            member_gone(&shared, slot);
            return;
        }
        Err(e) => {
            eprintln!("fleet front: init not delivered to {peer}: {e}");
            member_gone(&shared, slot);
            return;
        }
    }
    match wire::read_frame(&mut reader) {
        Ok(Some(Frame::Ready { shard })) if shard == slot => {
            shared.members.beat(slot);
            shared.members.mark_up(slot);
            eprintln!("fleet front: {peer} joined as shard {slot}");
        }
        Ok(Some(Frame::Ready { shard })) => {
            eprintln!(
                "fleet front: {peer} identifies as shard {shard}, \
                 expected {slot}"
            );
            member_gone(&shared, slot);
            return;
        }
        Ok(Some(Frame::Fatal { msg })) => {
            eprintln!("fleet worker {slot}: {msg}");
            member_gone(&shared, slot);
            return;
        }
        Ok(Some(other)) => {
            eprintln!(
                "fleet front: expected ready from shard {slot}, got '{}'",
                other.kind()
            );
            member_gone(&shared, slot);
            return;
        }
        Ok(None) => {
            eprintln!(
                "fleet front: {peer} disconnected before the ready \
                 handshake"
            );
            member_gone(&shared, slot);
            return;
        }
        Err(e) => {
            eprintln!("fleet worker {slot}: {e}");
            member_gone(&shared, slot);
            return;
        }
    }

    // -- steady state: every inbound frame is liveness
    loop {
        match wire::read_frame(&mut reader) {
            Ok(Some(frame)) => {
                shared.members.beat(slot);
                match frame {
                    Frame::Reply { id, result } => {
                        let tx = lock(&handle.waiters).remove(&id);
                        if let (Some(tx), Ok(ok)) = (tx, result) {
                            let _ = tx.send(Response {
                                id,
                                output: ok.output,
                                latency_us: ok.latency_us,
                                batch_size: ok.batch_size,
                            });
                        }
                        // an error reply just dropped the sender: the
                        // caller's recv fails immediately
                    }
                    Frame::Heartbeat { .. } => {}
                    Frame::Steal => shared.hub.mark_hungry(slot),
                    frame @ Frame::Donate { .. } => {
                        let ids: Vec<RequestId> = match &frame {
                            Frame::Donate { requests, .. } => {
                                requests.iter().map(|r| r.id).collect()
                            }
                            _ => Vec::new(),
                        };
                        mediate_donation(
                            slot,
                            &frame,
                            &ids,
                            &shared.hub,
                            |s| {
                                lock(&shared.slots)
                                    .get(s)
                                    .map(|t| t.handle.clone())
                            },
                        );
                    }
                    Frame::Leave { .. } => {
                        shared.members.mark_draining(slot);
                        shared.hub.forget(slot);
                        eprintln!(
                            "fleet front: shard {slot} is leaving; \
                             re-hashing routes over the remaining members"
                        );
                    }
                    Frame::MetricsSnapshot {
                        streams,
                        rejected,
                        stolen,
                        donated,
                    } => {
                        let streams: BTreeMap<StreamKey, Metrics> = streams
                            .into_iter()
                            .map(|(family, k, m)| {
                                ((Arc::from(family.as_str()), k), m)
                            })
                            .collect();
                        lock(&shared.reports).insert(
                            slot,
                            ShardReport {
                                streams,
                                rejected,
                                stolen,
                                donated,
                            },
                        );
                        shared.members.mark_drained(slot);
                    }
                    Frame::Fatal { msg } => {
                        eprintln!("fleet worker {slot}: {msg}");
                        member_gone(&shared, slot);
                        return;
                    }
                    other => {
                        eprintln!(
                            "fleet front: unexpected '{}' frame from \
                             shard {slot}",
                            other.kind()
                        );
                        member_gone(&shared, slot);
                        return;
                    }
                }
            }
            Ok(None) => {
                member_gone(&shared, slot);
                return;
            }
            Err(e) => {
                // a socket torn down after a clean drain is expected;
                // anything else is worth a line in the log
                if shared.members.state(slot) != Some(MemberState::Drained)
                {
                    eprintln!("fleet worker {slot}: {e}");
                }
                member_gone(&shared, slot);
                return;
            }
        }
    }
}

// ---- the worker side ----------------------------------------------------

/// Entry point of `topkima fleet-worker --connect HOST:PORT`: dial the
/// fleet front (retrying while it binds), run the join → init → ready
/// handshake, then serve the shared worker event loop with heartbeats
/// enabled until shutdown, EOF, or the voluntary `--leave-after-ms`
/// departure.
pub fn run_fleet_worker(
    connect: &str,
    leave_after: Option<Duration>,
) -> Result<()> {
    let deadline = Instant::now() + DIAL_RETRY_BUDGET;
    let stream = loop {
        match TcpStream::connect(connect) {
            Ok(s) => break s,
            Err(e) => {
                if Instant::now() >= deadline {
                    bail!("dialing fleet front {connect}: {e}");
                }
                std::thread::sleep(Duration::from_millis(200));
            }
        }
    };
    let _ = stream.set_nodelay(true);
    let writer_half = stream
        .try_clone()
        .map_err(|e| anyhow!("cloning socket: {e}"))?;
    let mut out = BufWriter::new(writer_half);
    wire::write_frame(&mut out, &Frame::Join { pid: std::process::id() })
        .map_err(|e| anyhow!("join handshake: {e}"))?;
    let rx = spawn_frame_forwarder(stream);

    // -- init handshake (mirrors the pipe worker) -------------------------
    let (shard, shards, synthetic, config) = match rx.recv() {
        Ok(WorkerMsg::Frame(Frame::Init {
            shard,
            shards,
            synthetic,
            config,
        })) => (shard, shards, synthetic, config),
        Ok(WorkerMsg::Frame(other)) => {
            let msg =
                format!("expected init handshake, got '{}'", other.kind());
            fatal(&mut out, &msg);
            bail!("{msg}");
        }
        Ok(WorkerMsg::Bad(e)) => {
            fatal(&mut out, &e.to_string());
            bail!("{e}");
        }
        Err(_) => bail!("front closed the socket before the init handshake"),
    };
    if shards == 0 || shard >= shards {
        let msg = format!("init names shard {shard} of {shards}");
        fatal(&mut out, &msg);
        bail!("{msg}");
    }
    let builder = match crate::pipeline::StackConfig::from_json(&config)
        .and_then(|cfg| cfg.build())
    {
        Ok(b) => b,
        Err(e) => {
            let msg = format!("init config rejected: {e}");
            fatal(&mut out, &msg);
            bail!("{msg}");
        }
    };
    // Unlike the pipe worker there is no `shards == fleet.shards` check,
    // and *every* stream is registered: an elastic fleet re-hashes over
    // the live member set, so any stream can be routed (or donated)
    // here at some point in this worker's life.
    let mut router = Router::new();
    for def in builder.stream_defs() {
        router.register_def(def);
    }
    let mut executor = match builder.build_fleet_worker_executor(synthetic) {
        Ok(e) => e,
        Err(e) => {
            let msg = format!("fleet worker executor: {e}");
            fatal(&mut out, &msg);
            bail!("{msg}");
        }
    };
    wire::write_frame(&mut out, &Frame::Ready { shard })
        .map_err(|e| anyhow!("ready handshake: {e}"))?;

    let hb = HeartbeatConfig {
        interval_ms: builder.config().fleet.transport.heartbeat_ms,
        miss_budget: builder.config().fleet.transport.miss_budget,
    };
    let steal = builder.config().fleet.steal;
    let opts = WorkerOpts {
        shard,
        steal_enabled: steal.enabled,
        min_backlog: steal.min_backlog.max(1),
        heartbeat: Some(hb.interval()),
        leave_after,
    };
    run_worker_loop(&rx, &mut router, executor.as_mut(), &mut out, &opts)
}

#[cfg(test)]
mod tests {
    use super::*;

    use crate::coordinator::request::InputData;
    use crate::coordinator::transport::wire::ReplyOk;

    fn bind_pending(expect: usize) -> Option<TcpPending> {
        let opts = TcpOptions {
            expect,
            config: crate::pipeline::StackConfig::default().to_json(),
            synthetic: true,
            heartbeat: HeartbeatConfig::default(),
        };
        match TcpPending::bind("127.0.0.1:0", opts) {
            Ok(p) => Some(p),
            Err(e) => {
                eprintln!("SKIP: cannot bind loopback in this sandbox: {e}");
                None
            }
        }
    }

    #[test]
    fn join_timeout_is_typed_and_names_the_dial_command() {
        let Some(pending) = bind_pending(1) else { return };
        let err = pending
            .into_transport(Duration::from_millis(50))
            .err()
            .expect("no worker ever dials: timeout");
        let msg = err.to_string();
        assert!(msg.contains("fleet worker(s)"), "{msg}");
        assert!(msg.contains("fleet-worker --connect"), "{msg}");
    }

    #[test]
    fn wake_probe_without_join_allocates_no_slot() {
        let Some(pending) = bind_pending(0) else { return };
        let addr = pending.local_addr();
        drop(TcpStream::connect(addr).expect("loopback dial"));
        std::thread::sleep(Duration::from_millis(100));
        assert_eq!(pending.shared.members.total(), 0);
        let transport = pending
            .into_transport(Duration::from_secs(1))
            .expect("zero expected workers joins immediately");
        assert_eq!(Box::new(transport).shutdown().len(), 0);
    }

    /// An in-process fake worker speaking the raw protocol: the full
    /// join → init → ready → submit/reply → shutdown → snapshot cycle
    /// over a real loopback socket, no subprocess needed.
    #[test]
    fn handshake_and_round_trip_over_loopback() {
        let Some(pending) = bind_pending(1) else { return };
        let addr = pending.local_addr();
        let worker = std::thread::spawn(move || -> Result<(), WireError> {
            let stream = TcpStream::connect(addr)
                .map_err(|e| WireError::Io(e.to_string()))?;
            let mut out = BufWriter::new(
                stream
                    .try_clone()
                    .map_err(|e| WireError::Io(e.to_string()))?,
            );
            let mut reader = BufReader::new(stream);
            wire::write_frame(&mut out, &Frame::Join { pid: 4242 })?;
            let shard = match wire::read_frame(&mut reader)? {
                Some(Frame::Init { shard, .. }) => shard,
                other => {
                    return Err(WireError::Protocol(format!(
                        "expected init, got {other:?}"
                    )))
                }
            };
            wire::write_frame(&mut out, &Frame::Ready { shard })?;
            loop {
                match wire::read_frame(&mut reader)? {
                    Some(Frame::Submit { id, k, .. }) => {
                        wire::write_frame(
                            &mut out,
                            &Frame::Reply {
                                id,
                                result: Ok(ReplyOk {
                                    output: vec![k as f32],
                                    latency_us: 1.0,
                                    batch_size: 1,
                                }),
                            },
                        )?;
                    }
                    Some(Frame::Shutdown) => {
                        wire::write_frame(
                            &mut out,
                            &Frame::MetricsSnapshot {
                                streams: vec![(
                                    "bert".to_string(),
                                    5,
                                    Metrics::default(),
                                )],
                                rejected: 0,
                                stolen: 0,
                                donated: 0,
                            },
                        )?;
                        return Ok(());
                    }
                    Some(_) => {}
                    None => return Ok(()),
                }
            }
        });
        let mut transport = pending
            .into_transport(Duration::from_secs(10))
            .expect("fake worker joins");
        assert_eq!(transport.kind(), "tcp");
        assert_eq!(transport.shard_count(), 1);
        assert_eq!(transport.live_shards(), vec![0]);
        assert_eq!(transport.worker_pid(0), Some(4242));
        assert!(transport.membership_epoch() >= 1);
        let rx = transport
            .submit(
                0,
                Request::shared(
                    9,
                    Arc::from("bert"),
                    5,
                    Arc::new(InputData::I32(vec![1])),
                ),
            )
            .expect("routable shard accepts");
        let r = rx
            .recv_timeout(Duration::from_secs(10))
            .expect("reply crosses the socket");
        assert_eq!(r.output, vec![5.0]);
        let reports = Box::new(transport).shutdown();
        assert_eq!(reports.len(), 1);
        assert!(reports[0].is_some(), "drained snapshot stashed");
        worker
            .join()
            .expect("worker thread")
            .expect("worker protocol clean");
    }
}
