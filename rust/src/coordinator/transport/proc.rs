//! [`ProcessTransport`]: shards as `topkima shard-worker` subprocesses.
//!
//! The front spawns one worker per shard and speaks the versioned,
//! length-prefixed JSONL protocol of [`super::wire`] over the worker's
//! stdin/stdout (stderr is inherited, so worker diagnostics land in the
//! front's log). The handshake ships the *entire* `StackConfig` to the
//! worker, which rebuilds the pipeline from it — front and worker
//! derive stream policies, bucket lists, and executor costs from the
//! same validated value, so the two processes cannot drift.
//!
//! Per shard the front keeps a shared writer (submits + shutdown +
//! donation mediation), a reader thread (replies + the final metrics
//! snapshot), and a waiter map from request id to reply sender. Failure
//! is typed end to end: a worker that dies mid-load trips the shard's
//! `down` flag (EOF or a framing error on either pipe), the reader
//! drops every pending waiter so blocked `recv`s fail promptly instead
//! of hanging, subsequent submits return [`RouteError::ShardDown`], and
//! `Fleet::shutdown` reports the shard like a panicked thread
//! (`ShardPanic` with partial metrics).
//!
//! Work-stealing is mediated by the front over the `donate`/`steal`
//! frames (DESIGN.md §16): an idle worker announces hunger with
//! `steal`, a loaded worker ships surplus formed batches as `donate`,
//! and each reader thread pairs inbound donations with hungry live
//! peers through the shared [`StealHub`] — moving the donated requests'
//! reply waiters along so the thief's replies (and deaths) resolve
//! them. The worker half of the loop is shared with the TCP transport
//! ([`run_worker_loop`]), which adds heartbeats and voluntary leaves on
//! top.
//!
//! [`RouteError::ShardDown`]: crate::coordinator::RouteError::ShardDown
//! [`StealHub`]: crate::coordinator::membership::StealHub

use std::collections::{BTreeMap, HashMap};
use std::io::{BufReader, BufWriter, Write};
use std::process::{Child, ChildStdin, ChildStdout, Command, Stdio};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use crate::coordinator::batcher::BatchPlan;
use crate::coordinator::fleet::shard_of;
use crate::coordinator::membership::{
    lock, mediate_donation, send_locked, SlotHandle, StealHub, Waiters,
};
use crate::coordinator::metrics::Metrics;
use crate::coordinator::request::{InputData, Request, RequestId, Response};
use crate::coordinator::router::{RouteError, Router, StreamKey};
use crate::coordinator::server::Executor;
use crate::coordinator::shard::{ShardReport, IDLE_WAIT};
use crate::util::json::Json;

use super::wire::{self, DonatedRequest, Frame, ReplyError, ReplyOk, WireError};
use super::ShardTransport;

/// Wall-clock µs since the UNIX epoch (0 when the clock is unusable) —
/// the cross-process timestamp submit frames carry so worker-side
/// latency accounting can include pipe/socket transit (front and
/// workers share one host clock on pipes; across hosts the back-dating
/// degrades to worker-side-only measurement when clocks disagree).
pub(super) fn unix_us() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_micros() as u64)
        .unwrap_or(0)
}

/// Everything [`ProcessTransport::spawn`] needs, already resolved from
/// `StackConfig.fleet.transport` by the pipeline builder.
#[derive(Clone, Debug)]
pub struct ProcessOptions {
    /// Worker subprocesses to spawn. Must equal the shipped config's
    /// `fleet.shards` — routing and executor preload both partition by
    /// it, and every worker verifies the two agree before going ready.
    pub shards: usize,
    /// The full stack configuration, shipped verbatim in the `init`
    /// frame.
    pub config: Json,
    /// Worker binary path; `None` runs the current executable (the
    /// usual case: `topkima` spawning `topkima shard-worker`).
    pub worker: Option<String>,
    /// Extra environment variables for every worker.
    pub env: Vec<(String, String)>,
    /// Force the synthetic executor in workers (serve-fleet's load
    /// generator measures the control plane, not model accuracy).
    pub synthetic: bool,
}

/// One worker subprocess: pipes, shared slot handle, reader thread.
struct ProcShard {
    child: Child,
    handle: SlotHandle<BufWriter<ChildStdin>>,
    reader: Option<JoinHandle<Result<ShardReport, WireError>>>,
}

impl Drop for ProcShard {
    fn drop(&mut self) {
        // closing stdin is the EOF backstop: the worker's event loop
        // treats it like a shutdown frame, so the child always exits
        *lock(&self.handle.writer) = None;
        if let Some(handle) = self.reader.take() {
            let _ = handle.join();
        }
        let _ = self.child.wait();
    }
}

/// Cross-process shard transport (see the module docs).
pub struct ProcessTransport {
    shards: Vec<ProcShard>,
}

impl ProcessTransport {
    /// Spawn one `shard-worker` subprocess per shard and complete the
    /// wire handshake asynchronously (each shard's reader thread
    /// validates the `ready` frame). Fails loudly when a worker binary
    /// cannot be spawned at all; a worker that starts and then dies is
    /// a per-shard [`RouteError::ShardDown`], not a spawn failure.
    ///
    /// [`RouteError::ShardDown`]: crate::coordinator::RouteError::ShardDown
    pub fn spawn(opts: &ProcessOptions) -> Result<ProcessTransport, WireError> {
        // lint:allow(panic-path): spawn-time invariant — config validation rejects zero shards before any transport is built
        assert!(opts.shards > 0, "process transport needs at least one shard");
        let exe = match &opts.worker {
            Some(path) => std::path::PathBuf::from(path),
            None => std::env::current_exe().map_err(|e| {
                WireError::Io(format!("resolving current executable: {e}"))
            })?,
        };
        // First pass: spawn every child and ship its init frame, so the
        // whole fleet boots concurrently; readers start in the second
        // pass once the full slot table exists (donation mediation
        // needs every peer's handle).
        let mut pending: Vec<(Child, ChildStdout)> =
            Vec::with_capacity(opts.shards);
        let mut handles: Vec<SlotHandle<BufWriter<ChildStdin>>> =
            Vec::with_capacity(opts.shards);
        for shard in 0..opts.shards {
            let mut child = Command::new(&exe)
                .arg("shard-worker")
                .stdin(Stdio::piped())
                .stdout(Stdio::piped())
                .stderr(Stdio::inherit())
                .envs(opts.env.iter().map(|(k, v)| (k.clone(), v.clone())))
                .spawn()
                .map_err(|e| {
                    WireError::Io(format!(
                        "spawning shard worker {} ({}): {e}",
                        shard,
                        exe.display()
                    ))
                })?;
            // lint:allow(panic-path): Stdio::piped() above guarantees both handles exist on a freshly spawned child
            let stdin = child.stdin.take().expect("piped stdin");
            // lint:allow(panic-path): Stdio::piped() above guarantees both handles exist on a freshly spawned child
            let stdout = child.stdout.take().expect("piped stdout");
            let handle = SlotHandle {
                waiters: Arc::new(Mutex::new(HashMap::new())),
                writer: Arc::new(Mutex::new(Some(BufWriter::new(stdin)))),
                down: Arc::new(AtomicBool::new(false)),
            };
            let init = Frame::Init {
                shard,
                shards: opts.shards,
                synthetic: opts.synthetic,
                config: opts.config.clone(),
            };
            if let Err(e) = send_locked(&handle.writer, &init) {
                // a worker dead on arrival is a down shard, not a spawn
                // failure — submissions get typed ShardDown rejections
                eprintln!("shard worker {shard}: init not delivered: {e}");
                handle.down.store(true, Ordering::Release);
            }
            handles.push(handle);
            pending.push((child, stdout));
        }
        let slots = Arc::new(handles.clone());
        let hub = Arc::new(StealHub::new());
        let shards = pending
            .into_iter()
            .zip(handles)
            .enumerate()
            .map(|(shard, ((child, stdout), handle))| {
                let slots = slots.clone();
                let hub = hub.clone();
                let reader = std::thread::spawn(move || {
                    reader_loop(stdout, shard, slots, hub)
                });
                ProcShard { child, handle, reader: Some(reader) }
            })
            .collect();
        Ok(ProcessTransport { shards })
    }
}

impl ShardTransport for ProcessTransport {
    fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn kind(&self) -> &'static str {
        "process"
    }

    fn submit(
        &mut self,
        shard: usize,
        req: Request,
    ) -> Result<mpsc::Receiver<Response>, RouteError> {
        let key: StreamKey = (req.model.clone(), req.k);
        let Some(s) = self.shards.get(shard) else {
            // a router pointing at a shard this transport never had is
            // a routing bug; reject the request instead of panicking
            return Err(RouteError::ShardDown(key));
        };
        if s.handle.down.load(Ordering::Acquire) {
            return Err(RouteError::ShardDown(key));
        }
        let (tx, rx) = mpsc::channel();
        // insert before writing: the reply may race back before this
        // thread would regain the lock
        lock(&s.handle.waiters).insert(req.id, tx);
        let frame = Frame::Submit {
            id: req.id,
            family: req.model.to_string(),
            k: req.k,
            t_unix_us: unix_us(),
            input: req.input,
        };
        let delivered = match send_locked(&s.handle.writer, &frame) {
            Ok(true) => Ok(()),
            Ok(false) => {
                Err(WireError::Io("writer already closed".to_string()))
            }
            Err(e) => Err(e),
        };
        if let Err(e) = delivered {
            eprintln!("shard worker {shard}: submit not delivered: {e}");
            s.handle.down.store(true, Ordering::Release);
            lock(&s.handle.waiters).remove(&req.id);
            return Err(RouteError::ShardDown(key));
        }
        // Close the race with the reader's exit cleanup: the reader stores
        // `down` *before* clearing the waiter map, so if `down` still
        // reads false here our waiter either survives (live worker) or
        // was just swept by the clear (recv fails promptly) — but if it
        // reads true, our insert may have landed *after* the sweep and
        // would leak until transport drop. Never leave a waiter behind
        // on a dead shard.
        if s.handle.down.load(Ordering::Acquire) {
            lock(&s.handle.waiters).remove(&req.id);
            return Err(RouteError::ShardDown(key));
        }
        Ok(rx)
    }

    fn worker_pid(&self, shard: usize) -> Option<u32> {
        self.shards.get(shard).map(|s| s.child.id())
    }

    fn shutdown(mut self: Box<Self>) -> Vec<Option<ShardReport>> {
        // Signal every worker before joining any, so they drain their
        // queues concurrently; dropping the writer closes stdin, which
        // backstops the frame for a worker that missed it.
        for s in &mut self.shards {
            let _ = send_locked(&s.handle.writer, &Frame::Shutdown);
            *lock(&s.handle.writer) = None;
        }
        self.shards
            .iter_mut()
            .map(|s| {
                let report = s
                    .reader
                    .take()
                    .and_then(|handle| handle.join().ok())
                    .and_then(|result| result.ok());
                let _ = s.child.wait();
                report
            })
            .collect()
    }
}

/// Parse the worker's stdout until its final metrics snapshot: `ready`
/// handshake (version-checked), then replies dispatched to waiters and
/// steal-protocol frames mediated through the hub. Whatever the exit
/// path — snapshot, EOF, framing error, version skew — the shard is
/// marked down, every pending waiter is dropped (blocked callers fail
/// promptly instead of hanging on a dead worker), and the shard leaves
/// the hungry queue.
fn reader_loop(
    stdout: ChildStdout,
    shard: usize,
    slots: Arc<Vec<SlotHandle<BufWriter<ChildStdin>>>>,
    hub: Arc<StealHub>,
) -> Result<ShardReport, WireError> {
    let Some(me) = slots.get(shard).cloned() else {
        return Err(WireError::Protocol(format!(
            "reader for unknown shard {shard}"
        )));
    };
    let mut reader = BufReader::new(stdout);
    let result = (|| {
        match wire::read_frame(&mut reader)? {
            Some(Frame::Ready { shard: s }) if s == shard => {}
            Some(Frame::Ready { shard: s }) => {
                return Err(WireError::Protocol(format!(
                    "worker identifies as shard {s}, expected {shard}"
                )))
            }
            Some(Frame::Fatal { msg }) => {
                return Err(WireError::Protocol(format!("worker: {msg}")))
            }
            Some(other) => {
                return Err(WireError::Protocol(format!(
                    "expected ready handshake, got '{}'",
                    other.kind()
                )))
            }
            None => {
                return Err(WireError::Protocol(
                    "worker exited before the ready handshake".to_string(),
                ))
            }
        }
        loop {
            match wire::read_frame(&mut reader)? {
                Some(Frame::Reply { id, result }) => {
                    let tx = lock(&me.waiters).remove(&id);
                    if let (Some(tx), Ok(ok)) = (tx, result) {
                        let _ = tx.send(Response {
                            id,
                            output: ok.output,
                            latency_us: ok.latency_us,
                            batch_size: ok.batch_size,
                        });
                    }
                    // an error reply just dropped the sender: the
                    // caller's recv fails immediately, matching the
                    // local shard loop's rejection behavior
                }
                Some(Frame::Steal) => hub.mark_hungry(shard),
                Some(frame @ Frame::Donate { .. }) => {
                    let ids: Vec<RequestId> = match &frame {
                        Frame::Donate { requests, .. } => {
                            requests.iter().map(|r| r.id).collect()
                        }
                        _ => Vec::new(),
                    };
                    mediate_donation(shard, &frame, &ids, &hub, |s| {
                        slots.get(s).cloned()
                    });
                }
                Some(Frame::MetricsSnapshot {
                    streams,
                    rejected,
                    stolen,
                    donated,
                }) => {
                    let streams: BTreeMap<StreamKey, Metrics> = streams
                        .into_iter()
                        .map(|(family, k, m)| {
                            ((Arc::from(family.as_str()), k), m)
                        })
                        .collect();
                    return Ok(ShardReport {
                        streams,
                        rejected,
                        stolen,
                        donated,
                    });
                }
                Some(Frame::Fatal { msg }) => {
                    return Err(WireError::Protocol(format!("worker: {msg}")))
                }
                Some(other) => {
                    return Err(WireError::Protocol(format!(
                        "unexpected '{}' frame from worker",
                        other.kind()
                    )))
                }
                None => {
                    return Err(WireError::Protocol(
                        "worker exited without a metrics snapshot \
                         (killed or crashed)"
                            .to_string(),
                    ))
                }
            }
        }
    })();
    if let Err(e) = &result {
        eprintln!("shard worker {shard}: {e}");
    }
    me.down.store(true, Ordering::Release);
    // dropping the senders fails every pending recv — no hangs
    lock(&me.waiters).clear();
    hub.forget(shard);
    result
}

// ---- the worker side ----------------------------------------------------

pub(super) enum WorkerMsg {
    Frame(Frame),
    Bad(WireError),
}

enum Flow {
    Continue,
    Finish,
}

/// Spawn the forwarder thread that owns this worker's inbound byte
/// stream: frames (and the first framing error) go to the returned
/// channel, EOF becomes a channel disconnect. Shared by the pipe worker
/// (stdin) and the TCP worker (socket clone).
pub(super) fn spawn_frame_forwarder<R>(reader: R) -> mpsc::Receiver<WorkerMsg>
where
    R: std::io::Read + Send + 'static,
{
    let (tx, rx) = mpsc::channel::<WorkerMsg>();
    std::thread::spawn(move || {
        let mut reader = BufReader::new(reader);
        loop {
            match wire::read_frame(&mut reader) {
                Ok(Some(frame)) => {
                    if tx.send(WorkerMsg::Frame(frame)).is_err() {
                        return;
                    }
                }
                Ok(None) => return, // EOF → channel disconnect
                Err(e) => {
                    let _ = tx.send(WorkerMsg::Bad(e));
                    return;
                }
            }
        }
    });
    rx
}

/// Per-worker knobs of [`run_worker_loop`], beyond what the router and
/// executor already encode.
pub(super) struct WorkerOpts {
    /// This worker's shard slot (stamped on heartbeat/leave frames).
    pub shard: usize,
    /// Donate surplus formed batches / announce hunger when idle.
    pub steal_enabled: bool,
    /// Formed batches a donor keeps per round before donating
    /// (pre-clamped ≥ 1 by the caller).
    pub min_backlog: usize,
    /// Send a `heartbeat` frame at this cadence (TCP workers); `None`
    /// for pipe workers, whose liveness is the pipe itself.
    pub heartbeat: Option<Duration>,
    /// Announce a voluntary `leave` after this long, then drain and
    /// exit (scale-in testing hook; `None` = serve until shutdown).
    pub leave_after: Option<Duration>,
}

/// Mutable state of one worker event loop.
struct LoopState {
    streams: BTreeMap<StreamKey, Metrics>,
    rejected: u64,
    stolen: u64,
    donated: u64,
    families: HashMap<String, Arc<str>>,
    inputs: Vec<Arc<InputData>>,
    /// Donated batches received from the front, executed after our own
    /// ready batches each round.
    donations: Vec<(StreamKey, BatchPlan)>,
    /// A `steal` frame is in flight and no work has arrived since —
    /// don't re-announce hunger every idle tick.
    hungry: bool,
}

/// Entry point of `topkima shard-worker`: one shard event loop speaking
/// the wire protocol on stdin/stdout. Internal — the process transport
/// spawns it; it is not meant for interactive use (it blocks reading
/// the `init` frame).
///
/// The loop mirrors the in-process shard loop: sleep until the oldest
/// queued request's batching deadline, drain the whole arrival backlog
/// before forming batches, execute ready batches synchronously, flush
/// everything on shutdown (or EOF), then emit the final
/// `metrics_snapshot`. Batch *formation* is the same `Router`/`Batcher`
/// code the local transport runs, which is what makes deterministic
/// replay byte-identical across transports.
pub fn run_shard_worker() -> Result<()> {
    // All reading happens on the forwarder thread (one buffered reader
    // owns stdin); the main loop multiplexes frames and batching
    // deadlines through the channel, exactly like a shard thread.
    let rx = spawn_frame_forwarder(std::io::stdin());
    let mut out = BufWriter::new(std::io::stdout());

    // -- handshake --------------------------------------------------------
    let (shard, shards, synthetic, config) = match rx.recv() {
        Ok(WorkerMsg::Frame(Frame::Init {
            shard,
            shards,
            synthetic,
            config,
        })) => (shard, shards, synthetic, config),
        Ok(WorkerMsg::Frame(other)) => {
            let msg =
                format!("expected init handshake, got '{}'", other.kind());
            fatal(&mut out, &msg);
            bail!("{msg}");
        }
        Ok(WorkerMsg::Bad(e)) => {
            fatal(&mut out, &e.to_string());
            bail!("{e}");
        }
        Err(_) => bail!("front closed the pipe before the init handshake"),
    };
    if shards == 0 || shard >= shards {
        let msg = format!("init names shard {shard} of {shards}");
        fatal(&mut out, &msg);
        bail!("{msg}");
    }
    let builder = match crate::pipeline::StackConfig::from_json(&config)
        .and_then(|cfg| cfg.build())
    {
        Ok(b) => b,
        Err(e) => {
            let msg = format!("init config rejected: {e}");
            fatal(&mut out, &msg);
            bail!("{msg}");
        }
    };
    // The init frame's shard count must be the config's own: routing
    // (here) and executor preload (build_shard_executor) both partition
    // by shard count, and a disagreement would desync them silently —
    // streams routed to this shard whose executables were never loaded.
    if shards != builder.config().fleet.shards {
        let msg = format!(
            "init names {shards} shard(s) but the shipped config says \
             fleet.shards = {}",
            builder.config().fleet.shards
        );
        fatal(&mut out, &msg);
        bail!("{msg}");
    }
    let mut router = Router::new();
    for def in builder.stream_defs() {
        if shard_of(&def.key(), shards) == shard {
            router.register_def(def);
        }
    }
    // The executor is built *in this process* (PJRT handles never cross
    // threads, let alone processes) — artifacts when present and not
    // forced synthetic, the analytic-cost synthetic executor otherwise.
    let mut executor: Box<dyn Executor> =
        match builder.build_shard_executor(shard, synthetic) {
            Ok(e) => e,
            Err(e) => {
                let msg = format!("shard executor: {e}");
                fatal(&mut out, &msg);
                bail!("{msg}");
            }
        };
    wire::write_frame(&mut out, &Frame::Ready { shard })
        .map_err(|e| anyhow!("ready handshake: {e}"))?;

    let steal = builder.config().fleet.steal;
    let opts = WorkerOpts {
        shard,
        steal_enabled: steal.enabled,
        // `StackConfig::validate` rejects min_backlog = 0, but clamp at
        // the point of use like the local transport does: a donor must
        // keep at least one batch or it idles itself.
        min_backlog: steal.min_backlog.max(1),
        heartbeat: None,
        leave_after: None,
    };
    run_worker_loop(&rx, &mut router, executor.as_mut(), &mut out, &opts)
}

/// The worker event loop shared by the pipe worker (`shard-worker`) and
/// the TCP worker (`fleet-worker`): multiplex inbound frames with
/// batching deadlines, donate surplus, execute donated batches,
/// heartbeat when configured, and emit the final `metrics_snapshot`
/// after the shutdown (or EOF, or voluntary-leave) flush.
pub(super) fn run_worker_loop(
    rx: &mpsc::Receiver<WorkerMsg>,
    router: &mut Router,
    executor: &mut dyn Executor,
    out: &mut impl Write,
    opts: &WorkerOpts,
) -> Result<()> {
    let mut st = LoopState {
        streams: router
            .streams()
            .into_iter()
            .map(|key| (key, Metrics::default()))
            .collect(),
        rejected: 0,
        stolen: 0,
        donated: 0,
        families: HashMap::new(),
        inputs: Vec::new(),
        donations: Vec::new(),
        hungry: false,
    };
    let start = Instant::now();
    let mut last_beat = Instant::now();
    let mut left = false;
    loop {
        // liveness beacon first, so a long idle wait can never starve
        // the heartbeat budget
        if let Some(hb) = opts.heartbeat {
            if last_beat.elapsed() >= hb {
                wire::write_frame(out, &Frame::Heartbeat { shard: opts.shard })
                    .map_err(|e| anyhow!("heartbeat: {e}"))?;
                last_beat = Instant::now();
            }
        }
        let mut wait =
            router.next_deadline(Instant::now()).unwrap_or(IDLE_WAIT);
        if let Some(hb) = opts.heartbeat {
            let due = hb
                .saturating_sub(last_beat.elapsed())
                .max(Duration::from_millis(1));
            wait = wait.min(due);
        }
        if let Some(after) = opts.leave_after {
            let due = after
                .saturating_sub(start.elapsed())
                .max(Duration::from_millis(1));
            wait = wait.min(due);
        }
        let mut finish = false;
        match rx.recv_timeout(wait) {
            Ok(msg) => {
                if let Flow::Finish = handle_msg(msg, router, &mut st, out)? {
                    finish = true;
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => finish = true,
        }
        // Drain the whole backlog before forming batches so a burst
        // fills real buckets instead of timeout-firing as singles
        // (mirrors the local shard loop).
        while !finish {
            match rx.try_recv() {
                Ok(msg) => {
                    if let Flow::Finish =
                        handle_msg(msg, router, &mut st, out)?
                    {
                        finish = true;
                    }
                }
                Err(_) => break,
            }
        }
        // Voluntary departure: announce the leave (the front stops
        // routing here and re-hashes), then drain like a shutdown.
        if !finish && !left {
            if let Some(after) = opts.leave_after {
                if start.elapsed() >= after {
                    wire::write_frame(
                        out,
                        &Frame::Leave { shard: opts.shard },
                    )
                    .map_err(|e| anyhow!("leave: {e}"))?;
                    left = true;
                    finish = true;
                }
            }
        }
        let mut plans = if finish {
            router.flush()
        } else {
            router.ready_batches(Instant::now())
        };
        // Donor: keep `min_backlog` of this round's batches, ship the
        // surplus to the front *in formation order* as donate frames.
        // Formation already happened — only the execution site moves,
        // so composition is steal-invariant (the fleet_determinism
        // guarantee). Never on the finish path: the flush must account
        // every batch in this worker's own snapshot.
        if opts.steal_enabled && !finish && plans.len() > opts.min_backlog {
            for (key, plan) in plans.split_off(opts.min_backlog) {
                let frame = Frame::Donate {
                    family: key.0.to_string(),
                    k: key.1,
                    bucket: plan.bucket,
                    requests: plan
                        .requests
                        .iter()
                        .map(|r| DonatedRequest {
                            id: r.id,
                            input: r.input.clone(),
                        })
                        .collect(),
                };
                wire::write_frame(out, &frame)
                    .map_err(|e| anyhow!("donate: {e}"))?;
                st.donated += 1;
            }
        }
        let had_work = !plans.is_empty() || !st.donations.is_empty();
        for (key, plan) in plans {
            let metrics = st
                .streams
                .get_mut(&key)
                // lint:allow(panic-path): the router only forms batches for streams registered from the init frame; a miss is a worker bug worth a crash, not a recoverable error
                .expect("batch from registered stream");
            run_wire_batch(
                &key, plan, executor, metrics, &mut st.inputs, out,
            )?;
        }
        // Thief: execute donated batches after our own, on our own
        // metrics entry for the stream (created on demand — the fleet
        // front merges per-stream entries across shards).
        let donations: Vec<(StreamKey, BatchPlan)> =
            st.donations.drain(..).collect();
        for (key, plan) in donations {
            let metrics = st.streams.entry(key.clone()).or_default();
            run_wire_batch(
                &key, plan, executor, metrics, &mut st.inputs, out,
            )?;
            st.stolen += 1;
        }
        if finish {
            let snapshot = Frame::MetricsSnapshot {
                streams: st
                    .streams
                    .into_iter()
                    .map(|((family, k), m)| (family.to_string(), k, m))
                    .collect(),
                rejected: st.rejected,
                stolen: st.stolen,
                donated: st.donated,
            };
            // the front may already be gone on the EOF path; the
            // snapshot is then moot, not an error worth a nonzero exit
            let _ = wire::write_frame(out, &snapshot);
            return Ok(());
        }
        // Announce hunger once per idle stretch: nothing formed,
        // nothing donated to us, nothing queued.
        if opts.steal_enabled
            && !st.hungry
            && !had_work
            && router.queued() == 0
        {
            wire::write_frame(out, &Frame::Steal)
                .map_err(|e| anyhow!("steal: {e}"))?;
            st.hungry = true;
        }
    }
}

/// Intern a stream family string: the steady-state path is a map hit
/// with no allocation (§Perf: the event loop is a hot path).
fn intern(families: &mut HashMap<String, Arc<str>>, family: String) -> Arc<str> {
    match families.get(&family) {
        Some(model) => model.clone(),
        None => {
            let model: Arc<str> = Arc::from(family.as_str());
            families.insert(family, model.clone());
            model
        }
    }
}

/// Handle one frame from the front. Submissions are routed exactly like
/// the local shard loop's `admit`, except a rejection additionally
/// crosses the wire as a typed error reply (the front drops the waiter
/// so the caller's `recv` fails immediately).
fn handle_msg(
    msg: WorkerMsg,
    router: &mut Router,
    st: &mut LoopState,
    out: &mut impl Write,
) -> Result<Flow> {
    match msg {
        WorkerMsg::Frame(Frame::Submit { id, family, k, t_unix_us, input }) => {
            st.hungry = false;
            let model = intern(&mut st.families, family);
            // Back-date the enqueue instant by the observed pipe
            // transit, so end-to-end latency matches the local
            // transport's semantics (which times from front submission,
            // not shard receipt). Guarded: a zero/askew front clock or
            // an un-subtractable Instant falls back to "now", i.e. the
            // worker-side-only measurement.
            let now = Instant::now();
            let enqueued = match t_unix_us {
                0 => now,
                sent => now
                    .checked_sub(std::time::Duration::from_micros(
                        unix_us().saturating_sub(sent),
                    ))
                    .unwrap_or(now),
            };
            let req = Request { id, model, k, input, enqueued };
            if let Err(e) = router.route(req) {
                match &e {
                    // mirror the local admit(): admission-control
                    // rejections land on the stream, unknown streams on
                    // the shard counter
                    RouteError::QueueFull { stream, .. } => {
                        match st.streams.get_mut(stream) {
                            Some(m) => m.record_error(),
                            None => st.rejected += 1,
                        }
                    }
                    _ => st.rejected += 1,
                }
                wire::write_frame(
                    out,
                    &Frame::Reply {
                        id,
                        result: Err(ReplyError::Route(e)),
                    },
                )
                .map_err(|e| anyhow!("reply: {e}"))?;
            }
            Ok(Flow::Continue)
        }
        WorkerMsg::Frame(Frame::Donate { family, k, bucket, requests }) => {
            // a donated batch arrives pre-formed: reconstruct the plan
            // and queue it behind our own ready batches. Latency for
            // donated requests is measured from receipt here — their
            // true enqueue instant lives on the donor.
            st.hungry = false;
            let model = intern(&mut st.families, family);
            let key: StreamKey = (model.clone(), k);
            let now = Instant::now();
            let requests: Vec<Request> = requests
                .into_iter()
                .map(|d| Request {
                    id: d.id,
                    model: model.clone(),
                    k,
                    input: d.input,
                    enqueued: now,
                })
                .collect();
            st.donations.push((key, BatchPlan { requests, bucket }));
            Ok(Flow::Continue)
        }
        WorkerMsg::Frame(Frame::Poke) => Ok(Flow::Continue),
        WorkerMsg::Frame(Frame::Shutdown) => Ok(Flow::Finish),
        WorkerMsg::Frame(Frame::Steal) => {
            let msg = "'steal' frames flow worker → front only \
                       (the front mediates donations; it never asks a \
                       worker for work)";
            fatal(out, msg);
            bail!("{msg}");
        }
        WorkerMsg::Frame(Frame::Fatal { msg }) => {
            bail!("front reported fatal: {msg}");
        }
        WorkerMsg::Frame(other) => {
            let msg =
                format!("unexpected '{}' frame from front", other.kind());
            fatal(out, &msg);
            bail!("{msg}");
        }
        WorkerMsg::Bad(e) => {
            fatal(out, &e.to_string());
            bail!("{e}");
        }
    }
}

/// Execute one formed batch and stream the replies back. The
/// output-arity contract matches the local shard loop: a short (or
/// long) output vector fails the *batch* — every request gets a typed
/// error reply and an error count, none may report success.
fn run_wire_batch(
    key: &StreamKey,
    plan: BatchPlan,
    executor: &mut dyn Executor,
    metrics: &mut Metrics,
    inputs: &mut Vec<Arc<InputData>>,
    out: &mut impl Write,
) -> Result<()> {
    inputs.clear();
    inputs.extend(plan.requests.iter().map(|r| r.input.clone()));
    let outcome = executor.execute(key, inputs, plan.bucket);
    match outcome {
        Ok(outputs) if outputs.len() == plan.requests.len() => {
            let now = Instant::now();
            let mut lats = Vec::with_capacity(plan.requests.len());
            for (req, output) in plan.requests.iter().zip(outputs) {
                let latency_us =
                    now.duration_since(req.enqueued).as_secs_f64() * 1e6;
                lats.push(latency_us);
                wire::write_frame(
                    out,
                    &Frame::Reply {
                        id: req.id,
                        result: Ok(ReplyOk {
                            output,
                            latency_us,
                            batch_size: plan.bucket,
                        }),
                    },
                )
                .map_err(|e| anyhow!("reply: {e}"))?;
            }
            metrics.record_batch(&lats, plan.bucket, plan.padding());
        }
        Ok(short) => {
            fail_batch(
                &plan,
                format!(
                    "executor answered {} of {} requests",
                    short.len(),
                    plan.requests.len()
                ),
                metrics,
                out,
            )?;
        }
        Err(e) => {
            fail_batch(&plan, format!("executor failed: {e}"), metrics, out)?;
        }
    }
    Ok(())
}

fn fail_batch(
    plan: &BatchPlan,
    msg: String,
    metrics: &mut Metrics,
    out: &mut impl Write,
) -> Result<()> {
    for req in &plan.requests {
        metrics.record_error();
        wire::write_frame(
            out,
            &Frame::Reply {
                id: req.id,
                result: Err(ReplyError::Batch(msg.clone())),
            },
        )
        .map_err(|e| anyhow!("reply: {e}"))?;
    }
    Ok(())
}

/// Best-effort fatal frame (the peer may already be gone).
pub(super) fn fatal(out: &mut impl Write, msg: &str) {
    let _ = wire::write_frame(out, &Frame::Fatal { msg: msg.to_string() });
}
