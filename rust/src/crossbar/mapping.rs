//! Mapping a logical K^T onto physical crossbars + sub-top-k planning.
//!
//! When the crossbar is narrower than SL, K^T splits column-wise across
//! arrays and each array runs its own local top-k_i with Σk_i = k
//! (Sec. III-A "Considerations of crossbar size", Fig 4c). When the array
//! is shallower than d_k × 3 cells, weight precision drops (the paper's
//! 128×128 case: only 64 MAC rows → ternary weights instead of 4-bit).
//!
//! `split_columns` mirrors `python/compile/kernels/topk_softmax.crossbar_split`
//! exactly — parity is asserted in tests against the paper's examples.

/// Per-array slice of the sub-top-k plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Segment {
    /// First logical column of this array's slice.
    pub start: usize,
    /// Columns mapped to this array.
    pub width: usize,
    /// Local winners this array contributes (k_i).
    pub k: usize,
}

/// Split `d` logical columns over arrays `crossbar_cols` wide and
/// apportion global `k` by largest remainder (ties → earlier segment),
/// forcing every array ≥1 winner when k allows.
pub fn split_columns(d: usize, k: usize, crossbar_cols: usize) -> Vec<Segment> {
    assert!(d > 0 && crossbar_cols > 0);
    let n_seg = d.div_ceil(crossbar_cols);
    let widths: Vec<usize> = (0..n_seg)
        .map(|i| crossbar_cols.min(d - i * crossbar_cols))
        .collect();
    let mut ks = vec![0usize; n_seg];
    if n_seg == 1 {
        ks[0] = k;
    } else {
        let mut base: Vec<usize> =
            widths.iter().map(|&w| k * w / d).collect();
        let fracs: Vec<usize> = widths.iter().map(|&w| (k * w) % d).collect();
        let mut order: Vec<usize> = (0..n_seg).collect();
        order.sort_by_key(|&i| (std::cmp::Reverse(fracs[i]), i));
        let rem = k - base.iter().sum::<usize>();
        for i in 0..rem {
            base[order[i % n_seg]] += 1;
        }
        if k >= n_seg {
            for j in 0..n_seg {
                while base[j] == 0 {
                    let donor = (0..n_seg)
                        .max_by_key(|&t| base[t])
                        .expect("nonempty");
                    base[donor] -= 1;
                    base[j] += 1;
                }
            }
        }
        ks = base;
    }
    let mut start = 0;
    widths
        .into_iter()
        .zip(ks)
        .map(|(width, k)| {
            let s = Segment { start, width, k };
            start += width;
            s
        })
        .collect()
}

/// Weight precision (bits incl. sign) affordable on an array with `rows`
/// physical rows after `replica_rows`: each extra cell in the gang adds
/// one magnitude bit (1 cell → ternary ≈ 2b, 3 cells → 15 levels ≈ 4b).
pub fn precision_for(rows: usize, replica_rows: usize, depth: usize) -> u32 {
    let mac_rows = rows.saturating_sub(replica_rows);
    let cells_per_weight = (mac_rows / depth.max(1)).clamp(0, 3);
    match cells_per_weight {
        0 => 0,          // doesn't fit at all
        1 => 2,          // ternary {-1,0,1}
        2 => 3,          // ±3 levels
        _ => 4,          // full 15-level gang
    }
}

/// Apply a precision downgrade to 15-level codes: requantize onto the
/// coarser grid the smaller array can store.
pub fn downgrade_codes(codes: &[i32], bits: u32) -> Vec<i32> {
    assert!((2..=4).contains(&bits));
    let max_code = match bits {
        2 => 1,
        3 => 3,
        _ => 7,
    };
    codes
        .iter()
        .map(|&c| {
            // scale -7..7 onto -max..max, round to nearest
            let scaled =
                (c as f64 * max_code as f64 / 7.0).round() as i32;
            scaled.clamp(-max_code, max_code)
        })
        .collect()
}

/// Global-top-k oracle vs the fragmented plan: selection sets as column
/// index lists (used by Fig 4c analysis and tests).
pub fn sub_topk_select(scores: &[f64], segments: &[Segment]) -> Vec<usize> {
    let mut picked = Vec::new();
    for seg in segments {
        let slice = &scores[seg.start..seg.start + seg.width];
        let mut idx: Vec<usize> = (0..slice.len()).collect();
        idx.sort_by(|&a, &b| {
            slice[b].partial_cmp(&slice[a]).unwrap().then(a.cmp(&b))
        });
        picked.extend(idx.iter().take(seg.k).map(|&i| i + seg.start));
    }
    picked.sort_unstable();
    picked
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_split_256() {
        let segs = split_columns(384, 5, 256);
        assert_eq!(
            segs,
            vec![
                Segment { start: 0, width: 256, k: 3 },
                Segment { start: 256, width: 128, k: 2 },
            ]
        );
    }

    #[test]
    fn paper_split_128() {
        let ks: Vec<usize> =
            split_columns(384, 5, 128).iter().map(|s| s.k).collect();
        assert_eq!(ks, vec![2, 2, 1]);
    }

    #[test]
    fn k_conserved_widths_cover_d() {
        for (d, k, w) in [(384, 5, 256), (100, 7, 30), (64, 1, 16),
                          (4096, 5, 256), (17, 3, 5)] {
            let segs = split_columns(d, k, w);
            assert_eq!(segs.iter().map(|s| s.width).sum::<usize>(), d);
            assert_eq!(segs.iter().map(|s| s.k).sum::<usize>(), k);
            let mut pos = 0;
            for s in &segs {
                assert_eq!(s.start, pos);
                pos += s.width;
            }
        }
    }

    #[test]
    fn paper_fig4c_example_selection() {
        // scores 1..384, 128-wide arrays, k=5 → [127,128,255,256,384]
        // (1-based values; 0-based indices shifted by one)
        let scores: Vec<f64> = (1..=384).map(|v| v as f64).collect();
        let segs = split_columns(384, 5, 128);
        let sel = sub_topk_select(&scores, &segs);
        let values: Vec<usize> = sel.iter().map(|&i| i + 1).collect();
        assert_eq!(values, vec![127, 128, 255, 256, 384]);
    }

    #[test]
    fn single_array_equals_global_topk() {
        let scores = vec![0.3, 9.0, -2.0, 5.5, 5.5, 1.0];
        let segs = split_columns(6, 3, 6);
        assert_eq!(sub_topk_select(&scores, &segs), vec![1, 3, 4]);
    }

    #[test]
    fn precision_matches_paper_cases() {
        // 256×256, 64 replica, depth 64 → 192/64 = 3 cells → 4 bits
        assert_eq!(precision_for(256, 64, 64), 4);
        // 128×128, 64 replica, depth 64 → 64/64 = 1 cell → ternary
        assert_eq!(precision_for(128, 64, 64), 2);
    }

    #[test]
    fn downgrade_preserves_sign_and_order() {
        let codes: Vec<i32> = (-7..=7).collect();
        let tern = downgrade_codes(&codes, 2);
        assert!(tern.iter().all(|c| (-1..=1).contains(c)));
        assert_eq!(tern[0], -1);
        assert_eq!(tern[14], 1);
        assert_eq!(tern[7], 0);
        let four = downgrade_codes(&codes, 4);
        assert_eq!(four, codes);
    }

    #[test]
    fn property_split_matches_python_mirror() {
        // Deterministic cross-check against values generated from the
        // python crossbar_split for a grid of cases (recorded inline).
        let cases: &[(usize, usize, usize, &[usize])] = &[
            (384, 5, 256, &[3, 2]),
            (384, 5, 128, &[2, 2, 1]),
            (100, 3, 32, &[1, 1, 1, 0]),
            (64, 5, 64, &[5]),
        ];
        for (d, k, w, want) in cases {
            let ks: Vec<usize> =
                split_columns(*d, *k, *w).iter().map(|s| s.k).collect();
            assert_eq!(&ks, want, "d={d} k={k} w={w}");
        }
    }
}
