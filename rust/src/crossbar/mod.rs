//! SRAM/RRAM crossbar arrays and K^T weight mapping (Sec. III-A).
//!
//! * [`Crossbar`] — one physical array: ternary-cell weight storage
//!   (3 cells per 15-level weight), integer MAC against PWM input codes,
//!   write latency/energy accounting, replica-row budget for the IMA.
//! * [`mapping`] — splitting a logical K^T (d_k × SL) across arrays whose
//!   column/row budget is smaller, and apportioning the global k into
//!   per-array sub-top-k (`split_columns`, mirroring the python
//!   `crossbar_split`).

pub mod mapping;

use crate::circuits::sram_cell::CellColumn;
use crate::circuits::Timing;
use crate::util::simd;

/// Target size of one column tile of weight codes in `mac_rows_into` —
/// small enough to stay L1-resident while every query row of a batch
/// streams over it (i32 codes: 16 KiB ≈ half a typical 32 KiB L1d,
/// leaving room for the input row and outputs).
const L1_TILE_BYTES: usize = 16 * 1024;

/// Technology of an IMC array (Sec. III-A: RRAM for static projection
/// weights, SRAM for the per-input K^T / V).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Tech {
    Sram,
    Rram,
}

/// One physical crossbar array storing a weight tile column-major.
#[derive(Clone, Debug)]
pub struct Crossbar {
    pub tech: Tech,
    /// Physical rows (bitcells per column), incl. replica rows.
    pub rows: usize,
    /// Physical columns.
    pub cols: usize,
    /// Replica rows reserved for ramp generation + calibration (64 in the
    /// paper's 256×256 instance: 32 ramp + 32 calibration).
    pub replica_rows: usize,
    /// Stored weight columns (quantized codes, one CellColumn per used
    /// output column) — the cell-level ground truth.
    columns: Vec<CellColumn>,
    /// Flat column-major copy of the weight codes ([col][row]) used by
    /// the MAC hot path; equals `unpack(columns)` exactly (§Perf: the
    /// per-cell walk cost ~9× in cache misses and mults — see
    /// EXPERIMENTS.md §Perf).
    codes_flat: Vec<i32>,
    /// Logical contraction depth (weights per column).
    depth: usize,
}

impl Crossbar {
    /// Rows available for MAC weights after the replica budget.
    pub fn mac_rows(rows: usize, replica_rows: usize) -> usize {
        rows - replica_rows
    }

    /// Max logical weights per column at 3 cells/weight.
    pub fn weight_capacity(rows: usize, replica_rows: usize) -> usize {
        Self::mac_rows(rows, replica_rows) / crate::quant::CELLS_PER_WEIGHT
    }

    /// Program a weight tile `kt[depth][n_cols]` (15-level codes) into a
    /// fresh array. Panics if the tile exceeds the physical budget —
    /// mapping decisions belong to [`mapping`], not here.
    pub fn program(
        tech: Tech,
        rows: usize,
        cols: usize,
        replica_rows: usize,
        kt_codes: &[Vec<i32>],
    ) -> Crossbar {
        let depth = kt_codes.len();
        assert!(depth <= Self::weight_capacity(rows, replica_rows),
                "tile depth {depth} exceeds capacity");
        let n_cols = kt_codes.first().map_or(0, Vec::len);
        assert!(n_cols <= cols, "tile cols {n_cols} exceed {cols}");
        // 15-level code contract (|w| ≤ WEIGHT_LEVELS): this bound is
        // what lets mac_into accumulate in i32 without overflow.
        debug_assert!(
            kt_codes
                .iter()
                .flatten()
                .all(|&w| w.abs() <= crate::quant::WEIGHT_LEVELS),
            "weight code outside ±{}", crate::quant::WEIGHT_LEVELS
        );
        let mut codes_flat = Vec::with_capacity(n_cols * depth);
        let columns = (0..n_cols)
            .map(|c| {
                let col: Vec<i32> =
                    kt_codes.iter().map(|row| row[c]).collect();
                codes_flat.extend_from_slice(&col);
                CellColumn::from_weight_codes(&col)
            })
            .collect();
        Crossbar { tech, rows, cols, replica_rows, columns, codes_flat, depth }
    }

    /// Used output columns.
    pub fn used_cols(&self) -> usize {
        self.columns.len()
    }

    /// Logical contraction depth.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Integer MAC of one input-code vector against every used column —
    /// what the bitlines present to the IMA for one conversion.
    pub fn mac_all(&self, input_codes: &[i32]) -> Vec<i64> {
        let mut out = vec![0i64; self.columns.len()];
        self.mac_into(input_codes, &mut out);
        out
    }

    /// MAC into a caller-provided buffer — the simulator hot path.
    ///
    /// Works on the flat per-column weight codes rather than walking the
    /// three ternary cells of each weight: identical arithmetic (cells
    /// reconstruct the code exactly — see `mac_matches_cell_level`), one
    /// contiguous stream per column. The accumulator stays in i32 so the
    /// loop vectorizes as full-width integer lanes (§Perf): |w·x| ≤ 105
    /// and depth is bounded by the physical row budget (rows/3), so the
    /// column sum is far below i32::MAX for any programmable array.
    pub fn mac_into(&self, input_codes: &[i32], out: &mut [i64]) {
        assert_eq!(input_codes.len(), self.depth);
        assert_eq!(out.len(), self.columns.len());
        // Overflow guard for the i32 accumulator: weights are bounded at
        // program() time (±WEIGHT_LEVELS), inputs here (±qmax(5) = 15),
        // so each product is ≤ 105 and the depth bound keeps every
        // column sum far below i32::MAX.
        debug_assert!(self.depth < (i32::MAX / 128) as usize);
        debug_assert!(
            input_codes
                .iter()
                .all(|&x| x.abs() <= crate::quant::qmax(
                    crate::quant::N_BITS_INPUT
                )),
            "input code outside the 5-bit PWM range"
        );
        let d = self.depth;
        for (c, o) in out.iter_mut().enumerate() {
            let col = &self.codes_flat[c * d..(c + 1) * d];
            // SIMD i32 lanes, widened to the i64 output here. Wrapping
            // lane sums are exact under the |w·x| ≤ 105 / bounded-depth
            // contract asserted above.
            *o = simd::dot_i32(col, input_codes) as i64;
        }
    }

    /// Batched MAC of several input rows against every used column,
    /// into a row-major flat buffer (`out[r·cols + c]`), resized by the
    /// callee. Bit-identical to calling [`Self::mac_into`] per row.
    ///
    /// Cache-blocked (§Perf): columns are processed in tiles of
    /// ~[`L1_TILE_BYTES`] of weight codes, and each tile is reused
    /// across *all* rows of the batch before moving on — the weight
    /// tile stays L1-hot instead of being re-streamed from L2/DRAM for
    /// every row. The per-row single-tile order equals the per-column
    /// order of `mac_into`, and each dot product is computed by the
    /// same kernel, so tiling cannot change a single bit.
    pub fn mac_rows_into(&self, q_rows: &[Vec<i32>], out: &mut Vec<i64>) {
        let d = self.depth;
        let cols = self.columns.len();
        for q in q_rows {
            assert_eq!(q.len(), d);
        }
        out.clear();
        out.resize(q_rows.len() * cols, 0);
        let tile_cols = if d == 0 {
            cols.max(1)
        } else {
            (L1_TILE_BYTES / (4 * d)).clamp(8, 256).min(cols.max(1))
        };
        let mut tile_start = 0usize;
        while tile_start < cols {
            let tile_end = (tile_start + tile_cols).min(cols);
            for (r, q) in q_rows.iter().enumerate() {
                let row = &mut out[r * cols + tile_start..r * cols + tile_end];
                for (c, o) in (tile_start..tile_end).zip(row.iter_mut()) {
                    let col = &self.codes_flat[c * d..(c + 1) * d];
                    *o = simd::dot_i32(col, q) as i64;
                }
            }
            tile_start = tile_end;
        }
    }

    /// Cell-level MAC (reference path, used by parity tests).
    pub fn mac_cells(&self, input_codes: &[i32]) -> Vec<i64> {
        self.columns.iter().map(|col| col.mac(input_codes)).collect()
    }

    /// Deterministic heap footprint of the programmed tile, bytes:
    /// element counts × element sizes, never allocator capacities, so
    /// the number is byte-stable across runs and platforms. The chunked
    /// attention path charges each live tile against its peak-scratch
    /// accounting with this.
    pub fn footprint_bytes(&self) -> usize {
        let cells: usize = self.columns.iter().map(|col| col.len()).sum();
        let per_cell = std::mem::size_of::<
            crate::circuits::sram_cell::TernaryCell,
        >() + std::mem::size_of::<i32>();
        cells * per_cell
            + self.codes_flat.len() * std::mem::size_of::<i32>()
    }

    /// Write latency for (re)programming the used tile, ns. SRAM arrays
    /// are written row-by-row with column-parallel cells (Sec. IV-B:
    /// one row per write cycle).
    pub fn write_latency_ns(&self, t: &Timing) -> f64 {
        let phys_rows = self.depth * crate::quant::CELLS_PER_WEIGHT;
        phys_rows as f64 * t.t_write_row
    }

    /// Write energy, pJ (per-cell dynamic write cost).
    pub fn write_energy_pj(&self, e_write_cell: f64) -> f64 {
        let cells =
            self.depth * crate::quant::CELLS_PER_WEIGHT * self.used_cols();
        cells as f64 * e_write_cell
    }

    /// Worst-case |MAC| the stored tile can produce against n-bit inputs;
    /// the replica-row calibration uses this as the ADC full scale.
    pub fn full_scale_mac(&self, n_bits_input: u32) -> f64 {
        let qm = crate::quant::qmax(n_bits_input) as i64;
        let worst: i64 = self
            .columns
            .iter()
            .map(|col| {
                (0..col.len())
                    .map(|i| {
                        col.cells[i].value().unsigned_abs() as i64
                            * col.scales[i] as i64
                    })
                    .sum::<i64>()
            })
            .max()
            .unwrap_or(1);
        (worst * qm).max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tile(depth: usize, cols: usize) -> Vec<Vec<i32>> {
        (0..depth)
            .map(|r| (0..cols).map(|c| ((r * 7 + c * 3) % 15) as i32 - 7).collect())
            .collect()
    }

    #[test]
    fn capacity_matches_paper_examples() {
        // 256×256 with 64 replica rows → 192 MAC rows → 64 weights of 4b
        assert_eq!(Crossbar::weight_capacity(256, 64), 64);
        // 128×128 with 64 replica rows → 64 MAC rows → 21 full ternary
        // gangs; the paper instead drops to ternary precision (1 cell per
        // weight) — that trade-off lives in mapping::precision_for.
        assert_eq!(Crossbar::weight_capacity(128, 64), 21);
    }

    #[test]
    fn mac_matches_integer_oracle() {
        let kt = tile(8, 5);
        let xb = Crossbar::program(Tech::Sram, 256, 256, 64, &kt);
        let x: Vec<i32> = vec![3, -15, 8, 0, 2, -1, 14, 7];
        let got = xb.mac_all(&x);
        for c in 0..5 {
            let want: i64 = (0..8)
                .map(|r| kt[r][c] as i64 * x[r] as i64)
                .sum();
            assert_eq!(got[c], want, "col {c}");
        }
    }

    #[test]
    fn mac_matches_cell_level() {
        // hot path (flat codes) == ground truth (ternary cell walk)
        let kt = tile(16, 9);
        let xb = Crossbar::program(Tech::Sram, 256, 256, 64, &kt);
        let x: Vec<i32> = (0..16).map(|i| ((i * 11) % 31) as i32 - 15).collect();
        assert_eq!(xb.mac_all(&x), xb.mac_cells(&x));
    }

    #[test]
    fn mac_into_matches_mac_all() {
        let kt = tile(4, 3);
        let xb = Crossbar::program(Tech::Sram, 64, 16, 16, &kt);
        let x = vec![1, -2, 3, -4];
        let mut buf = vec![0i64; 3];
        xb.mac_into(&x, &mut buf);
        assert_eq!(buf, xb.mac_all(&x));
    }

    #[test]
    fn mac_rows_into_matches_per_row_mac() {
        // the cache-blocked batched path is bit-identical to row-at-a-
        // time mac_into, tails and all (40 cols is not a tile multiple)
        let kt = tile(16, 40);
        let xb = Crossbar::program(Tech::Sram, 256, 256, 64, &kt);
        let rows: Vec<Vec<i32>> = (0..6)
            .map(|r| {
                (0..16).map(|i| ((r * 5 + i * 3) % 31) as i32 - 15).collect()
            })
            .collect();
        let mut flat = Vec::new();
        xb.mac_rows_into(&rows, &mut flat);
        assert_eq!(flat.len(), 6 * 40);
        for (r, q) in rows.iter().enumerate() {
            assert_eq!(
                &flat[r * 40..(r + 1) * 40],
                xb.mac_all(q).as_slice(),
                "row {r}"
            );
        }
        xb.mac_rows_into(&[], &mut flat);
        assert!(flat.is_empty());
    }

    #[test]
    fn write_cost_scales_with_tile() {
        let t = Timing::default();
        let small = Crossbar::program(Tech::Sram, 256, 256, 64, &tile(4, 4));
        let big = Crossbar::program(Tech::Sram, 256, 256, 64, &tile(64, 4));
        assert!(big.write_latency_ns(&t) > small.write_latency_ns(&t));
        assert_eq!(big.write_latency_ns(&t), 64.0 * 3.0 * 5.0);
    }

    #[test]
    fn full_scale_bounds_every_mac() {
        let kt = tile(16, 8);
        let xb = Crossbar::program(Tech::Sram, 256, 256, 64, &kt);
        let fs = xb.full_scale_mac(5);
        let x: Vec<i32> = (0..16).map(|i| if i % 2 == 0 { 15 } else { -15 }).collect();
        for &m in &xb.mac_all(&x) {
            assert!((m as f64).abs() <= fs);
        }
    }

    #[test]
    #[should_panic(expected = "exceeds capacity")]
    fn overdeep_tile_rejected() {
        let _ = Crossbar::program(Tech::Sram, 128, 128, 64, &tile(40, 4));
    }
}
