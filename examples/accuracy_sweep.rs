//! Fig 3 re-check through the full rust stack.
//!
//! Runs every exported per-k model executable (trained with TFCBP at
//! k=5, then masked to each k at export) over the synthetic eval split
//! via PJRT and prints accuracy vs k — the rust-side confirmation of the
//! python Fig 3 sweep. Needs `make artifacts`.
//!
//! Run: `cargo run --release --example accuracy_sweep [-- --model vit]`

fn main() -> anyhow::Result<()> {
    use topkima::runtime::Engine;

    let args: Vec<String> = std::env::args().collect();
    let family = args
        .iter()
        .position(|a| a == "--model")
        .and_then(|i| args.get(i + 1))
        .cloned()
        .unwrap_or_else(|| "bert".to_string());
    let batch = 32usize;
    let limit = 512usize;

    let engine = Engine::new("artifacts")?;
    let eval = engine.manifest.eval_set(&family)?;
    let ks = engine.manifest.k_values(&family);
    println!(
        "Fig 3 re-check: {family}, {} eval samples, k in {ks:?}",
        eval.len()
    );
    println!("{:<8} {:>10} {:>14}", "k", "accuracy", "compile (ms)");

    for k in ks {
        let model = engine.load(&family, k, batch)?;
        let n = (limit.min(eval.len()) / batch) * batch;
        let stride = eval.x_stride();
        let mut correct = 0usize;
        for b0 in (0..n).step_by(batch) {
            let out = if eval.kind == "vit" {
                model.run_f32(&eval.x_f32[b0 * stride..(b0 + batch) * stride])?
            } else {
                model.run_i32(&eval.x_i32[b0 * stride..(b0 + batch) * stride])?
            };
            let per = out.len() / batch;
            for i in 0..batch {
                let o = &out[i * per..(i + 1) * per];
                let idx = b0 + i;
                let ok = if eval.kind == "vit" {
                    argmax(o) as i32 == eval.y_i32[idx]
                } else {
                    let sl = o.len() / 2;
                    let starts: Vec<f32> =
                        (0..sl).map(|t| o[t * 2]).collect();
                    let ends: Vec<f32> =
                        (0..sl).map(|t| o[t * 2 + 1]).collect();
                    argmax(&starts) as i32 == eval.y_i32[idx * 2]
                        && argmax(&ends) as i32 == eval.y_i32[idx * 2 + 1]
                };
                correct += ok as usize;
            }
        }
        let label = if k == 0 { "full".into() } else { k.to_string() };
        println!(
            "{label:<8} {:>10.3} {:>14.0}",
            correct as f64 / n as f64,
            model.compile_ms
        );
    }
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}
