//! End-to-end serving driver (the repo's headline example).
//!
//! Loads the TFCBP-trained BERT-tiny artifacts, starts the coordinator
//! (router + dynamic batcher + PJRT executor) through the pipeline
//! builder, replays the synthetic SQuAD eval split as a Poisson-ish
//! request trace, and reports:
//!
//! * answer exact-match accuracy through the full rust serving path,
//! * p50/p95/p99 latency, throughput, batch occupancy,
//! * the co-simulated hardware cost of the same trace on the
//!   Topkima-Former fabric (TOPS, TOPS/W, softmax-macro speedup) —
//!   i.e. what this trace would cost on the paper's silicon.
//!
//! Every layer is assembled from ONE `StackConfig`, so the served k, the
//! co-simulated sparsity, and the coordinator's stream key can't drift.
//!
//! Run: `make artifacts && cargo run --release --example serve`
//! Flags: `--requests N` (default 256), `--model bert|vit`, `--k K`,
//! `--max-wait-us U`, or `--config stack.json`.

use std::time::Duration;

use topkima::coordinator::InputData;
use topkima::pipeline::{ModelKind, StackConfig};
use topkima::softmax::SoftmaxKind;
use topkima::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let cfg = StackConfig::from_args_with(
        StackConfig::default().with_model(ModelKind::BertTiny),
        &args,
    )?;
    let b = cfg.build()?;
    let family = b.config().model.family();
    let k = b.config().k;

    // ---- load artifacts + eval trace ------------------------------------
    let engine = b.engine()?;
    println!("platform {}", engine.platform());
    let buckets = b.buckets(&engine);
    anyhow::ensure!(!buckets.is_empty(), "no artifacts for {family} k={k}");
    let ckpt = &engine.manifest.checkpoints[family];
    println!(
        "{family} checkpoint: {} params, trained eval acc {:.3}",
        ckpt.params, ckpt.accuracy
    );
    println!("serve buckets {buckets:?}");
    let eval = engine.manifest.eval_set(family)?;

    // ---- start coordinator ----------------------------------------------
    let mut coord = b.start_coordinator(buckets);

    // ---- replay the trace with jittered arrivals -------------------------
    let n = b.config().serving.requests.min(eval.len());
    let stride = eval.x_stride();
    let mut rng = Rng::new(2026);
    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        let input = if eval.kind == "vit" {
            InputData::F32(eval.x_f32[i * stride..(i + 1) * stride].to_vec())
        } else {
            InputData::I32(eval.x_i32[i * stride..(i + 1) * stride].to_vec())
        };
        rxs.push(coord.submit(family, k, input));
        // bursty arrivals: occasionally pause so the batcher sees both
        // full and timeout-formed batches
        if rng.chance(0.05) {
            std::thread::sleep(Duration::from_micros(500));
        }
    }

    let mut correct = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(300))?;
        let o = &resp.output;
        let ok = if eval.kind == "vit" {
            argmax(o) as i32 == eval.y_i32[i]
        } else {
            let sl = o.len() / 2;
            let starts: Vec<f32> = (0..sl).map(|t| o[t * 2]).collect();
            let ends: Vec<f32> = (0..sl).map(|t| o[t * 2 + 1]).collect();
            argmax(&starts) as i32 == eval.y_i32[i * 2]
                && argmax(&ends) as i32 == eval.y_i32[i * 2 + 1]
        };
        correct += ok as usize;
    }
    let wall = t0.elapsed().as_secs_f64();
    let metrics = coord.shutdown().expect("coordinator shut down cleanly");

    println!("\n== serving metrics ==\n{}", metrics.summary());
    println!(
        "exact match: {:.3} ({correct}/{n}); wall {:.2}s = {:.1} req/s",
        correct as f64 / n as f64,
        wall,
        n as f64 / wall
    );

    // ---- co-simulate the same trace on the Topkima-Former fabric ---------
    println!("\n== hardware co-simulation of this trace ==");
    let tc = b.transformer();
    for kind in SoftmaxKind::ALL {
        // skip kinds this config can't express (k = 0 is conv-only)
        let Ok(bb) = b.config().clone().with_softmax(kind).build() else {
            continue;
        };
        let r = bb.simulate();
        let module_ns = r.latency_ns();
        let module_pj = r.energy_pj();
        let total_ms =
            module_ns * tc.n_layers as f64 * n as f64 / 1e6;
        let total_mj =
            module_pj * tc.n_layers as f64 * n as f64 / 1e9;
        println!(
            "{:<12} {n} requests x {} layers: {:.2} ms, {:.3} mJ \
             ({:.2} TOPS, {:.2} TOPS/W)",
            kind.name(),
            tc.n_layers,
            total_ms,
            total_mj,
            r.tops(),
            r.tops_per_watt()
        );
    }
    Ok(())
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
        .map(|(i, _)| i)
        .unwrap_or(0)
}
