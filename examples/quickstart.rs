//! Quickstart: the whole stack in one file, every layer assembled
//! through the `topkima::pipeline` builder.
//!
//! 1. Circuit level — run the topkima macro on a toy crossbar and watch
//!    it pick the top-k columns with early stopping.
//! 2. Architecture level — simulate one BERT-base attention module and
//!    print the Table-I-style summary.
//! 3. Serving level (optional) — if `artifacts/` exists, load the AOT
//!    BERT model through PJRT and answer one synthetic SQuAD query.
//!
//! Run: `cargo run --release --example quickstart`

use topkima::pipeline::StackConfig;
use topkima::sim::report;
use topkima::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // ---- 1. circuit level: one topkima-SM conversion --------------------
    println!("== 1. topkima macro on a toy 8-col crossbar ==");
    let toy = StackConfig::default()
        .with_geometry(64, 16, 16)
        .with_k(3)
        .build()?;
    let depth = 4;
    // K^T codes: column j gets a distinctive weight pattern
    let kt: Vec<Vec<i32>> = (0..depth)
        .map(|r| (0..8).map(|c| ((r + c) % 15) as i32 - 7).collect())
        .collect();
    let mut rng = Rng::new(1);
    let topkima = toy.build_macro(&kt, &mut rng);
    let q = vec![vec![5, -3, 7, 2]];
    let (probs, cost) = topkima.run(&q, &mut rng);
    println!("attention row: {:?}", probs[0]
        .iter().map(|p| (p * 1000.0).round() / 1000.0).collect::<Vec<_>>());
    println!(
        "-> exactly 3 non-zero scores, early-stop alpha = {:.2}, \
         latency {:.0} ns, energy {:.0} pJ\n",
        cost.alpha, cost.latency_ns, cost.energy_pj
    );

    // ---- 2. architecture level: one attention module --------------------
    println!("== 2. BERT-base attention module on the fabric ==");
    let base = StackConfig::default().build()?;
    let r = base.simulate();
    println!("{}\n", report::system_summary(&r));

    // ---- 3. serving level: PJRT inference (needs `make artifacts`) ------
    println!("== 3. AOT model through PJRT ==");
    match base.engine() {
        Ok(engine) => {
            let eval = engine.manifest.eval_set("bert")?;
            let model = engine.load("bert", 5, 1)?;
            let stride = eval.x_stride();
            let out = model.run_i32(&eval.x_i32[..stride])?;
            let sl = out.len() / 2;
            let start = (0..sl)
                .max_by(|&a, &b| out[a * 2].partial_cmp(&out[b * 2]).unwrap())
                .unwrap();
            let end = (0..sl)
                .max_by(|&a, &b| {
                    out[a * 2 + 1].partial_cmp(&out[b * 2 + 1]).unwrap()
                })
                .unwrap();
            println!(
                "predicted span ({start}, {end}); gold ({}, {})",
                eval.y_i32[0], eval.y_i32[1]
            );
        }
        Err(e) => {
            println!("artifacts not built ({e}); run `make artifacts` first");
        }
    }
    Ok(())
}
