//! Full hardware evaluation report — every figure/table in one run.
//!
//! Prints Fig 4a (macro ratios), Fig 4d (scale schemes), Fig 4e/f
//! (component breakdown), Fig 4g/h (operation breakdown) and Table I for
//! the configured workload, all derived from one `StackConfig`.
//! `--seq-len N` overrides SL; `--table1` prints only the comparison
//! table; every other pipeline flag (`--k`, `--alpha`, `--model`, ...)
//! works too.
//!
//! Run: `cargo run --release --example hw_report [-- --seq-len 4096]`

use topkima::accel;
use topkima::circuits::{BlockDims, Energy, Timing};
use topkima::pipeline::StackConfig;
use topkima::scale::ScaleImpl;
use topkima::sim::report;
use topkima::softmax::SoftmaxKind;

fn main() -> anyhow::Result<()> {
    let mut args: Vec<String> = std::env::args().skip(1).collect();
    let table1_only = args.iter().any(|a| a == "--table1");
    args.retain(|a| a != "--table1");

    let cfg = StackConfig::from_args(&args)?;
    let b = cfg.clone().build()?;
    let tc = b.transformer();
    let sc = b.sim_config();
    let seq_len = tc.seq_len;

    if !table1_only {
        let t = Timing::default();
        let e = Energy::default();
        let (d, k, alpha) = (seq_len, b.config().k, b.config().alpha);
        let dims = BlockDims { d, rows: 64 * 3, k };
        println!("== Fig 4a (Eq 3/4, d={d}, k={k}, alpha={alpha}) ==");
        println!(
            "speed: {:.1}x vs conv-SM, {:.1}x vs Dtopk-SM",
            t.conv_sm(d) / t.topkima_sm(d, k, alpha),
            t.dtopk_sm(d, k) / t.topkima_sm(d, k, alpha)
        );
        println!(
            "energy: {:.1}x vs conv-SM, {:.1}x vs Dtopk-SM\n",
            e.conv_sm(&dims, &t) / e.topkima_sm(&dims, &t, alpha),
            e.dtopk_sm(&dims, &t) / e.topkima_sm(&dims, &t, alpha)
        );

        println!("== Fig 4d (per score row) ==");
        let row_base = t.t_pwm_input() + t.t_ima_arb(alpha, k);
        for s in [ScaleImpl::LeftShift, ScaleImpl::TronFreeScale] {
            let c = s.cost(1, d, &t);
            println!(
                "scale-free is {:.2}x faster than {}",
                (row_base + c.latency_ns) / row_base,
                s.name()
            );
        }

        let r = b.simulate();
        println!("\n== Fig 4e/f ==\n{}", report::component_table(&r));
        println!("== Fig 4g/h ==\n{}", report::operation_table(&r));
        for kind in SoftmaxKind::ALL {
            // skip kinds this config can't express (k = 0 is conv-only)
            let Ok(bb) = cfg.clone().with_softmax(kind).build() else {
                continue;
            };
            println!("{}", report::system_summary(&bb.simulate()));
        }
        println!();
    }

    println!("== Table I ==");
    let point = accel::system_point(&tc, &sc);
    print!("{}", accel::render_table(&point));
    for (name, speed, ee) in accel::comparison(&point) {
        println!(
            "vs {name:<15} speed {}  EE {}",
            speed.map_or("    - ".into(), |s| format!("{s:6.1}x")),
            ee.map_or("    - ".into(), |e| format!("{e:6.1}x")),
        );
    }
    Ok(())
}
