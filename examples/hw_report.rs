//! Full hardware evaluation report — every figure/table in one run.
//!
//! Prints Fig 4a (macro ratios), Fig 4d (scale schemes), Fig 4e/f
//! (component breakdown), Fig 4g/h (operation breakdown) and Table I for
//! the paper's BERT-base workload. `--seq-len N` overrides SL;
//! `--table1` prints only the comparison table.
//!
//! Run: `cargo run --release --example hw_report [-- --seq-len 4096]`

use topkima::accel;
use topkima::circuits::{BlockDims, Energy, Timing};
use topkima::model::TransformerConfig;
use topkima::scale::ScaleImpl;
use topkima::sim::{report, simulate_attention, SimConfig, SoftmaxKind};

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let seq_len = args
        .iter()
        .position(|a| a == "--seq-len")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(384usize);
    let table1_only = args.iter().any(|a| a == "--table1");

    let tc = TransformerConfig::bert_base().with_seq_len(seq_len);
    let sc = SimConfig::default();

    if !table1_only {
        let t = Timing::default();
        let e = Energy::default();
        let (d, k, alpha) = (seq_len, tc.topk, sc.alpha);
        let dims = BlockDims { d, rows: 64 * 3, k };
        println!("== Fig 4a (Eq 3/4, d={d}, k={k}, alpha={alpha}) ==");
        println!(
            "speed: {:.1}x vs conv-SM, {:.1}x vs Dtopk-SM",
            t.conv_sm(d) / t.topkima_sm(d, k, alpha),
            t.dtopk_sm(d, k) / t.topkima_sm(d, k, alpha)
        );
        println!(
            "energy: {:.1}x vs conv-SM, {:.1}x vs Dtopk-SM\n",
            e.conv_sm(&dims, &t) / e.topkima_sm(&dims, &t, alpha),
            e.dtopk_sm(&dims, &t) / e.topkima_sm(&dims, &t, alpha)
        );

        println!("== Fig 4d (per score row) ==");
        let row_base = t.t_pwm_input() + t.t_ima_arb(alpha, k);
        for s in [ScaleImpl::LeftShift, ScaleImpl::TronFreeScale] {
            let c = s.cost(1, d, &t);
            println!(
                "scale-free is {:.2}x faster than {}",
                (row_base + c.latency_ns) / row_base,
                s.name()
            );
        }

        let r = simulate_attention(&tc, &sc);
        println!("\n== Fig 4e/f ==\n{}", report::component_table(&r));
        println!("== Fig 4g/h ==\n{}", report::operation_table(&r));
        for softmax in [
            SoftmaxKind::Conventional,
            SoftmaxKind::Dtopk,
            SoftmaxKind::Topkima,
        ] {
            let r = simulate_attention(
                &tc,
                &SimConfig { softmax, ..SimConfig::default() },
            );
            println!("{}", report::system_summary(&r));
        }
        println!();
    }

    println!("== Table I ==");
    let point = accel::system_point(&tc, &sc);
    print!("{}", accel::render_table(&point));
    for (name, speed, ee) in accel::comparison(&point) {
        println!(
            "vs {name:<15} speed {}  EE {}",
            speed.map_or("    - ".into(), |s| format!("{s:6.1}x")),
            ee.map_or("    - ".into(), |e| format!("{e:6.1}x")),
        );
    }
}
