#!/usr/bin/env bash
# CI for the Topkima-Former workspace. Works fully offline (all
# dependencies are vendored path crates).
#
# Steps:
#   1. cargo fmt --check    (advisory unless CI_STRICT=1)
#   2. cargo clippy -D warnings (advisory unless CI_STRICT=1)
#   3. tier-1 gate: cargo build --release && cargo test -q
#   4. smoke: `topkima check` (skips cleanly when no artifacts exist)
#   5. smoke: `topkima sweep-hw` on a tiny grid (JSON baseline emitted)
#   6. perf baseline: `cargo bench --bench perf_hotpath` writes
#      BENCH_hotpath.json (machine-readable numbers for EXPERIMENTS.md
#      §Perf)
#
# Exit code reflects the tier-1 gate + smoke steps; fmt/clippy failures
# only fail the run when CI_STRICT=1 (they may be unavailable offline).

set -u
cd "$(dirname "$0")"

strict="${CI_STRICT:-0}"
status=0

note() { printf '\n== %s ==\n' "$*"; }

advisory() {
    # run "$@"; demote failure to a warning unless CI_STRICT=1
    if "$@"; then
        return 0
    fi
    if [ "$strict" = "1" ]; then
        echo "FAIL (strict): $*"
        status=1
    else
        echo "WARN (advisory): $* failed or unavailable"
    fi
}

note "rustfmt"
if cargo fmt --version >/dev/null 2>&1; then
    advisory cargo fmt --check
else
    echo "WARN: rustfmt not installed; skipping"
fi

note "clippy"
if cargo clippy --version >/dev/null 2>&1; then
    advisory cargo clippy --all-targets -- -D warnings
else
    echo "WARN: clippy not installed; skipping"
fi

note "tier-1: build"
if ! cargo build --release; then
    echo "FAIL: cargo build --release"
    exit 1
fi

note "tier-1: test"
if ! cargo test -q; then
    echo "FAIL: cargo test -q"
    exit 1
fi

note "smoke: topkima check"
if ! cargo run --release --quiet -- check; then
    echo "FAIL: topkima check"
    status=1
fi

note "smoke: topkima sweep-hw (tiny grid, 2 threads)"
if cargo run --release --quiet -- sweep-hw \
        --threads 2 --ks 1,5 --seq-lens 64 \
        --kinds dtopk,topkima --noise-points ideal \
        --q-rows 2 --out BENCH_sweep_smoke.json \
    && [ -s BENCH_sweep_smoke.json ]; then
    echo "ok: BENCH_sweep_smoke.json written"
else
    echo "FAIL: topkima sweep-hw smoke"
    status=1
fi

note "perf baseline: cargo bench --bench perf_hotpath"
if cargo bench --bench perf_hotpath && [ -s BENCH_hotpath.json ]; then
    echo "ok: BENCH_hotpath.json written"
else
    echo "FAIL: perf_hotpath bench"
    status=1
fi

if [ "$status" = "0" ]; then
    note "CI green"
else
    note "CI failed"
fi
exit "$status"
