#!/usr/bin/env bash
# CI for the Topkima-Former workspace. Works fully offline (all
# dependencies are vendored path crates).
#
# Steps:
#   1. cargo fmt --check    (advisory unless CI_STRICT=1)
#   2. cargo clippy -D warnings (advisory unless CI_STRICT=1)
#   3. tier-1 gate: cargo build --release && cargo test -q
#   3b. lint gate (HARD): `topkima lint --format json` — the in-repo
#      static analyzer (DESIGN.md §12: schema-sync, panic-path,
#      lock-discipline, unknown-field). Any unsuppressed finding fails
#      the run; the JSON report lands in BENCH_lint.json
#   4. smoke: `topkima check` (skips cleanly when no artifacts exist)
#   5. smoke: `topkima sweep-hw` on a tiny grid (JSON baseline emitted)
#   5c. smoke: `topkima sweep-hw` over the full 6-design accelerator
#      registry (conv,dtopk,topkima,ita,hyft,sole) → BENCH_sweep_zoo.json
#   6. smoke: `topkima serve-fleet` (sharded fleet under synthetic load;
#      BENCH_fleet.json emitted, fails on any dropped request)
#   6b. smoke: `topkima serve-fleet --ab topkima,sole` — one fleet
#      A/B-ing two registry designs as two streams
#   5d. nightly long-context tier (opt-in, TOPKIMA_NIGHTLY=1): one
#      1,048,576-column topkima point through the streaming engine
#      (GeneratedKeys — K^T is never materialized). Skipped loudly in
#      the default run; set TOPKIMA_NIGHTLY=1 to arm it
#   3c. SIMD parity gate (HARD): rerun the parity suites
#      (scratch_parity, sweep_determinism, simd_parity, macro_parity,
#      chunked_parity) with TOPKIMA_SIMD=off — the default-mode run is
#      covered by tier-1, so together both dispatch decisions are
#      proven bit-identical
#   5b. long-context tier: `topkima sweep-hw --chunk-cols 256` at
#      4k and 64k key columns → BENCH_sweep_long.json, then the HARD
#      `topkima longctx-gate`: peak scratch at 64k must stay under 8x
#      the 4k figure (16x the sequence), or the streaming path has
#      regressed to O(seq) state. The same report renders the
#      EXPERIMENTS.md §Long-context table (LONGCTX_TABLE markers)
#   7. smoke: export a tiny eval trace and replay it through ALL THREE
#      fleet↔shard transports in deterministic mode — twice over the
#      local transport (stealing on), once over the process transport
#      (shard-worker subprocesses + wire protocol), and once over the
#      tcp transport (fleet-worker processes dialing a loopback front,
#      stealing on, front-mediated) — and `cmp` all the BENCH files:
#      replay must be deterministic AND transport-invariant (the
#      ShardTransport redesign is behavior-preserving). The tcp leg
#      SKIPs loudly when the sandbox cannot bind a loopback port.
#      The same trace is then replayed with
#      `--behavioral` (real circuit-macro batches) under BOTH SIMD
#      modes and cmp'ed against the synthetic replay: deterministic
#      metrics are schedule-determined, so the behavioral executor and
#      the SIMD dispatch decision must not move them
#   8. perf baseline: `cargo bench --bench perf_hotpath` runs twice —
#      default dispatch → BENCH_hotpath.json, TOPKIMA_SIMD=off →
#      BENCH_hotpath_scalar.json — each stamped with its dispatch
#      decision (machine-readable numbers for EXPERIMENTS.md §Perf)
#   9. bench-diff: compare the fresh BENCH_hotpath.json,
#      BENCH_sweep_smoke.json, and BENCH_fleet_replay.json (the
#      deterministic replay — reproducible batching metrics, not
#      wall-clock tails) against baselines/ and FAIL on >25%
#      regressions. Every file logs a loud GATING or SEEDING line: a
#      missing baseline is auto-seeded from this run's numbers (commit
#      it to arm the gate — a SEEDING line means that file was NOT
#      gated). A metric present in the baseline but missing from the
#      fresh run is a hard failure
#  10. refresh the EXPERIMENTS.md §Perf table between the
#      PERF_TABLE_BEGIN/END markers, and the scalar-vs-SIMD table
#      between the SIMD_TABLE_BEGIN/END markers, from the fresh numbers
#  11. refresh the EXPERIMENTS.md cross-accelerator Table 1 between the
#      TABLE1_BEGIN/END markers from `topkima accel-table --markdown`
#      (calibrated registry ratios at the paper's d=384, k=5 point)
#
# Exit code reflects the tier-1 gate + the lint gate + smoke steps;
# fmt/clippy failures only fail the run when CI_STRICT=1 (they may be
# unavailable offline — the skip is loud when they are).

set -u
cd "$(dirname "$0")"

strict="${CI_STRICT:-0}"
status=0

note() { printf '\n== %s ==\n' "$*"; }

advisory() {
    # run "$@"; demote failure to a warning unless CI_STRICT=1
    if "$@"; then
        return 0
    fi
    if [ "$strict" = "1" ]; then
        echo "FAIL (strict): $*"
        status=1
    else
        echo "WARN (advisory): $* failed or unavailable"
    fi
}

note "rustfmt"
if cargo fmt --version >/dev/null 2>&1; then
    advisory cargo fmt --check
else
    echo "WARN: rustfmt NOT INSTALLED — formatting was NOT checked this" \
         "run (install the rustfmt component, or rely on a CI runner" \
         "that has it; CI_STRICT=1 still cannot check what is absent)"
fi

note "clippy"
if cargo clippy --version >/dev/null 2>&1; then
    advisory cargo clippy --all-targets -- -D warnings
else
    echo "WARN: clippy NOT INSTALLED — lints were NOT checked this run" \
         "(the in-repo \`topkima lint\` gate below still runs; install" \
         "the clippy component to restore the full surface)"
fi

note "tier-1: build"
if ! cargo build --release; then
    echo "FAIL: cargo build --release"
    exit 1
fi

note "tier-1: test"
if ! cargo test -q; then
    echo "FAIL: cargo test -q"
    exit 1
fi

note "simd parity gate: parity suites under TOPKIMA_SIMD=off (hard)"
# Tier-1 above ran every test under the default dispatch decision
# (AVX2 where detected). Rerunning the parity suites with the SIMD
# layer forced off proves both code paths produce bit-identical
# results — the acceptance harness of the vectorization pass.
if ! TOPKIMA_SIMD=off cargo test -q \
        --test scratch_parity --test sweep_determinism \
        --test simd_parity --test macro_parity \
        --test chunked_parity; then
    echo "FAIL: parity suites diverge under TOPKIMA_SIMD=off"
    exit 1
fi
echo "ok: parity suites bit-identical with SIMD forced off"

note "lint gate: topkima lint (hard — any finding fails the run)"
# The self-hosted analyzer (DESIGN.md §12). Machine-readable report is
# kept next to the BENCH files; on failure the human-readable fix list
# is printed so the offending lines are one click away.
if cargo run --release --quiet -- lint --format json > BENCH_lint.json; then
    echo "ok: lint clean (report in BENCH_lint.json)"
else
    echo "lint findings:"
    cargo run --release --quiet -- lint --fix-list || true
    echo "FAIL: topkima lint (fix the findings above, or suppress with"
    echo "      '// lint:allow(<checker>): <reason>' — see DESIGN.md §12)"
    exit 1
fi

note "smoke: topkima check"
if ! cargo run --release --quiet -- check; then
    echo "FAIL: topkima check"
    status=1
fi

note "smoke: topkima sweep-hw (tiny grid, 2 threads)"
if cargo run --release --quiet -- sweep-hw \
        --threads 2 --ks 1,5 --seq-lens 64 \
        --kinds dtopk,topkima --noise-points ideal \
        --q-rows 2 --out BENCH_sweep_smoke.json \
    && [ -s BENCH_sweep_smoke.json ]; then
    echo "ok: BENCH_sweep_smoke.json written"
else
    echo "FAIL: topkima sweep-hw smoke"
    status=1
fi

note "smoke: topkima sweep-hw (6-design accelerator zoo grid)"
# Every registered design — the legacy three plus the rival zoo — runs
# through the same sweep harness on one tiny point each. This is the
# registry's end-to-end smoke: a kind that parses but cannot simulate
# fails here, not in a user's sweep.
if cargo run --release --quiet -- sweep-hw \
        --threads 2 --ks 5 --seq-lens 64 \
        --kinds conv,dtopk,topkima,ita,hyft,sole --noise-points ideal \
        --q-rows 1 --out BENCH_sweep_zoo.json \
    && [ -s BENCH_sweep_zoo.json ]; then
    echo "ok: BENCH_sweep_zoo.json written (all 6 registry designs swept)"
else
    echo "FAIL: topkima sweep-hw accelerator-zoo smoke"
    status=1
fi

note "long-context tier: sweep-hw --chunk-cols 256 at 4k and 64k"
# The streaming attention engine never materializes the score row:
# peak_scratch_bytes per point is deterministic element-count
# accounting, so the growth gate below is exact, not a wall-clock band.
if cargo run --release --quiet -- sweep-hw \
        --threads 2 --ks 8 --seq-lens 4096,65536 \
        --kinds topkima --noise-points ideal \
        --q-rows 1 --chunk-cols 256 --out BENCH_sweep_long.json \
    && [ -s BENCH_sweep_long.json ]; then
    echo "ok: BENCH_sweep_long.json written (64k point completed)"
else
    echo "FAIL: long-context sweep (64k chunked point)"
    status=1
fi

note "long-context gate: peak scratch 64k < 8x 4k (hard)"
# 16x the sequence for < 8x the scratch — O(seq) state would blow this
if cargo run --release --quiet -- longctx-gate \
        --report BENCH_sweep_long.json --max-ratio 8; then
    echo "ok: scratch stays chunk-bounded as the sequence grows"
else
    echo "FAIL: longctx-gate (streaming path regressed to O(seq) state)"
    status=1
fi

note "nightly long-context tier: 1M-column point (TOPKIMA_NIGHTLY=1)"
# One 2^20-column topkima point through the streaming chunked engine.
# GeneratedKeys synthesizes key codes on demand, so K^T is never
# materialized — peak state stays chunk-bounded even at a million
# columns. Too slow for every push; nightly runners arm it.
if [ "${TOPKIMA_NIGHTLY:-0}" = "1" ]; then
    if cargo run --release --quiet -- sweep-hw \
            --threads 2 --ks 8 --seq-lens 1048576 \
            --kinds topkima --noise-points ideal \
            --q-rows 1 --chunk-cols 256 --out BENCH_sweep_1m.json \
        && [ -s BENCH_sweep_1m.json ]; then
        echo "ok: BENCH_sweep_1m.json written (1,048,576-column point)"
    else
        echo "FAIL: nightly 1M-column sweep point"
        status=1
    fi
else
    echo "SKIP: nightly 1M-column point NOT run (set TOPKIMA_NIGHTLY=1" \
         "to run it — this default run proves nothing about the 1M tier)"
fi

note "smoke: topkima serve-fleet (2 shards, 3 streams, synthetic load)"
if cargo run --release --quiet -- serve-fleet \
        --duration-ms 200 --seed 7 --out BENCH_fleet.json \
    && [ -s BENCH_fleet.json ]; then
    echo "ok: BENCH_fleet.json written (zero dropped requests)"
else
    echo "FAIL: topkima serve-fleet smoke"
    status=1
fi

note "smoke: topkima serve-fleet --ab topkima,sole (registry A/B)"
# Two registry designs served side by side as two streams of one fleet:
# design A (topkima, top-k) vs design B (sole, dense). Proves the
# behavioral path can host a non-legacy design end to end.
if cargo run --release --quiet -- serve-fleet \
        --duration-ms 200 --seed 7 --ab topkima,sole \
        --out BENCH_fleet_ab.json \
    && [ -s BENCH_fleet_ab.json ]; then
    echo "ok: BENCH_fleet_ab.json written (topkima vs sole A/B)"
else
    echo "FAIL: topkima serve-fleet --ab smoke"
    status=1
fi

note "smoke: trace replay, all transports (byte-identical BENCH files)"
# export the synthetic schedule, then replay it deterministically four
# ways: twice through the 2-shard *local* transport with stealing on
# (the determinism guarantee), once through the *process* transport
# (shard-worker subprocesses over the wire protocol), and once through
# the *tcp* transport below. Every BENCH file must be byte-identical:
# deterministic replay metrics are schedule-determined, so they prove
# the ShardTransport boundary (and stealing)
# is behavior-invariant. The first replay is kept as
# BENCH_fleet_replay.json — its batching metrics are exactly
# reproducible, so THAT file (not the wall-clock live smoke) joins the
# bench-diff regression gate below.
trace=/tmp/topkima_ci_trace.jsonl
if cargo run --release --quiet -- serve-fleet \
        --duration-ms 120 --seed 11 --steal on \
        --export-trace "$trace" --out /tmp/topkima_ci_fleet_live.json \
    && cargo run --release --quiet -- serve-fleet \
        --trace "$trace" --steal on --deterministic \
        --out BENCH_fleet_replay.json \
    && cargo run --release --quiet -- serve-fleet \
        --trace "$trace" --steal on --deterministic \
        --out /tmp/topkima_ci_fleet_replay2.json \
    && cmp -s BENCH_fleet_replay.json \
              /tmp/topkima_ci_fleet_replay2.json; then
    echo "ok: trace replay is deterministic (identical BENCH files)"
else
    echo "FAIL: trace export/replay smoke (non-deterministic or dropped)"
    status=1
fi

if cargo run --release --quiet -- serve-fleet \
        --trace "$trace" --transport process --deterministic \
        --out /tmp/topkima_ci_fleet_replay_proc.json \
    && cmp -s BENCH_fleet_replay.json \
              /tmp/topkima_ci_fleet_replay_proc.json; then
    echo "ok: process-transport replay matches the local transport" \
         "byte-for-byte"
else
    echo "FAIL: process-transport replay diverges from local (or dropped)"
    status=1
fi

# TCP leg: two fleet-worker processes dial a loopback front and replay
# the same trace (stealing on — tcp stealing is front-mediated over the
# donate/steal frames). The BENCH file must still be byte-identical:
# deterministic metrics are schedule-determined, so neither the socket
# hop nor cross-host stealing may move them. Workers retry the dial for
# 10s, so starting them before the front binds is fine. A sandbox that
# cannot bind a loopback port skips this leg LOUDLY (nothing proven).
tcp_addr=127.0.0.1:17311
tcp_front_log=/tmp/topkima_ci_tcp_front.log
target/release/topkima fleet-worker --connect "$tcp_addr" \
    > /tmp/topkima_ci_tcp_w1.log 2>&1 &
tcp_w1=$!
target/release/topkima fleet-worker --connect "$tcp_addr" \
    > /tmp/topkima_ci_tcp_w2.log 2>&1 &
tcp_w2=$!
if cargo run --release --quiet -- serve-fleet \
        --trace "$trace" --transport tcp --transport-listen "$tcp_addr" \
        --steal on --deterministic \
        --out /tmp/topkima_ci_fleet_replay_tcp.json 2> "$tcp_front_log"; then
    if cmp -s BENCH_fleet_replay.json \
              /tmp/topkima_ci_fleet_replay_tcp.json; then
        echo "ok: tcp-transport replay matches the local transport" \
             "byte-for-byte (2 dialed-in workers, stealing on)"
    else
        echo "FAIL: tcp-transport replay diverges from local"
        status=1
    fi
elif grep -q "bind" "$tcp_front_log"; then
    echo "SKIP: tcp replay leg NOT run — this sandbox cannot bind a" \
         "loopback port ($(grep -m1 bind "$tcp_front_log")). The tcp" \
         "transport was NOT exercised this run"
else
    echo "FAIL: tcp-transport replay front exited nonzero:"
    cat "$tcp_front_log"
    status=1
fi
# front shutdown (or its bind failure + the 10s dial budget) ends both
# workers; reap them so the gate never leaks processes
wait "$tcp_w1" "$tcp_w2" 2>/dev/null

# Behavioral executors do real circuit-macro work per batch (batched
# MAC + batched top-k conversion — the §Perf hot paths) instead of a
# modeled sleep. Deterministic-replay metrics are schedule-determined,
# so the behavioral BENCH must match the synthetic one byte-for-byte —
# and must do so under BOTH SIMD dispatch decisions, which is the
# fleet-level leg of the scalar-vs-SIMD parity contract.
if cargo run --release --quiet -- serve-fleet \
        --trace "$trace" --steal on --deterministic --behavioral \
        --out /tmp/topkima_ci_fleet_replay_behav.json \
    && cmp -s BENCH_fleet_replay.json \
              /tmp/topkima_ci_fleet_replay_behav.json \
    && TOPKIMA_SIMD=off cargo run --release --quiet -- serve-fleet \
        --trace "$trace" --steal on --deterministic --behavioral \
        --out /tmp/topkima_ci_fleet_replay_behav_scalar.json \
    && cmp -s BENCH_fleet_replay.json \
              /tmp/topkima_ci_fleet_replay_behav_scalar.json; then
    echo "ok: behavioral replay matches synthetic under both SIMD modes"
else
    echo "FAIL: behavioral replay diverges (executor or SIMD mode moved" \
         "schedule-determined metrics)"
    status=1
fi

note "smoke: unknown subcommand fails loudly"
# a typo'd subcommand must exit nonzero (it used to print usage and
# exit 0, letting broken CI steps pass silently)
if cargo run --release --quiet -- no-such-subcommand >/dev/null 2>&1; then
    echo "FAIL: unknown subcommand exited 0"
    status=1
elif cargo run --release --quiet -- help serve-fleet >/dev/null \
        && cargo run --release --quiet -- help lint >/dev/null \
        && cargo run --release --quiet -- help fleet-worker \
            | grep -q -- --connect \
        && cargo run --release --quiet -- help serve-fleet \
            | grep -q -- --transport-heartbeat-ms; then
    echo "ok: unknown subcommand fails; help covers serve-fleet, lint," \
         "and fleet-worker (with the tcp membership flags)"
else
    echo "FAIL: topkima help serve-fleet / help lint / help fleet-worker"
    status=1
fi

note "perf baseline: cargo bench --bench perf_hotpath (both SIMD modes)"
# Two runs, each JSON stamped with its dispatch decision (avx2 /
# scalar / forced-off) so bench-diff warns instead of silently
# comparing numbers across ISAs.
if cargo bench --bench perf_hotpath -- --out BENCH_hotpath.json \
    && [ -s BENCH_hotpath.json ]; then
    echo "ok: BENCH_hotpath.json written"
else
    echo "FAIL: perf_hotpath bench"
    status=1
fi
if TOPKIMA_SIMD=off cargo bench --bench perf_hotpath -- \
        --out BENCH_hotpath_scalar.json \
    && [ -s BENCH_hotpath_scalar.json ]; then
    echo "ok: BENCH_hotpath_scalar.json written (TOPKIMA_SIMD=off)"
else
    echo "FAIL: perf_hotpath bench (TOPKIMA_SIMD=off)"
    status=1
fi

# -- bench-diff gate: fail on >25% regressions vs committed baselines --
# A missing baseline is seeded from this run (and should be committed);
# sweep numbers are deterministic, hotpath numbers are wall-clock, so
# the 25% band also absorbs machine-to-machine jitter.
bench_diff() {
    fresh="$1"
    base="baselines/$1"
    if [ ! -s "$fresh" ]; then
        echo "WARN: $fresh missing; skipping bench-diff"
        return
    fi
    if [ -s "$base" ]; then
        echo "GATING: $fresh vs committed $base (>25% regression fails)"
        if cargo run --release --quiet -- bench-diff \
                --baseline "$base" --fresh "$fresh" --max-regress 0.25; then
            echo "ok: $fresh within 25% of $base"
        else
            echo "FAIL: bench regression in $fresh vs $base"
            status=1
        fi
    else
        mkdir -p baselines
        cp "$fresh" "$base"
        echo "SEEDING: no committed baseline for $fresh — wrote $base" \
             "from this run's numbers. $fresh was NOT gated; commit" \
             "$base to arm the regression gate on the next run"
    fi
}

# Fleet metrics gate on the DETERMINISTIC replay (batch count /
# padding waste — exactly reproducible from the committed trace seed),
# not on the live smoke's wall-clock tail latencies, which drift far
# more than 25% on loaded runners with no code change.
note "bench-diff vs committed baselines (>25% fails)"
bench_diff BENCH_hotpath.json
bench_diff BENCH_sweep_smoke.json
bench_diff BENCH_fleet_replay.json

# -- EXPERIMENTS.md §Perf table: splice the fresh numbers in ----------
note "EXPERIMENTS.md §Perf table refresh"
if [ -s BENCH_hotpath.json ] \
        && grep -q PERF_TABLE_BEGIN EXPERIMENTS.md \
        && grep -q PERF_TABLE_END EXPERIMENTS.md; then
    base_flag=""
    if [ -s baselines/BENCH_hotpath.json ]; then
        base_flag="--baseline baselines/BENCH_hotpath.json"
    fi
    if cargo run --release --quiet -- bench-diff \
            --fresh BENCH_hotpath.json $base_flag --markdown \
            > /tmp/topkima_perf_table.md; then
        awk '
            /PERF_TABLE_BEGIN/ {
                print
                while ((getline line < "/tmp/topkima_perf_table.md") > 0)
                    print line
                skip = 1
                next
            }
            /PERF_TABLE_END/ { skip = 0 }
            skip == 0 { print }
        ' EXPERIMENTS.md > EXPERIMENTS.md.tmp \
            && mv EXPERIMENTS.md.tmp EXPERIMENTS.md
        echo "ok: EXPERIMENTS.md §Perf table refreshed"
    else
        echo "WARN: bench-diff --markdown failed; table left as-is"
    fi
else
    echo "WARN: no BENCH_hotpath.json or no markers; table left as-is"
fi

# -- EXPERIMENTS.md scalar-vs-SIMD table: speedup of the dispatched ----
# -- build over the forced-scalar build, same binary, same machine  ----
note "EXPERIMENTS.md §Perf scalar-vs-SIMD table refresh"
if [ -s BENCH_hotpath.json ] && [ -s BENCH_hotpath_scalar.json ] \
        && grep -q SIMD_TABLE_BEGIN EXPERIMENTS.md \
        && grep -q SIMD_TABLE_END EXPERIMENTS.md; then
    # baseline = scalar, fresh = dispatched: negative deltas are the
    # SIMD speedup. bench-diff prints the expected cross-dispatch WARN.
    if cargo run --release --quiet -- bench-diff \
            --baseline BENCH_hotpath_scalar.json \
            --fresh BENCH_hotpath.json --markdown \
            > /tmp/topkima_simd_table.md; then
        awk '
            /SIMD_TABLE_BEGIN/ {
                print
                while ((getline line < "/tmp/topkima_simd_table.md") > 0)
                    print line
                skip = 1
                next
            }
            /SIMD_TABLE_END/ { skip = 0 }
            skip == 0 { print }
        ' EXPERIMENTS.md > EXPERIMENTS.md.tmp \
            && mv EXPERIMENTS.md.tmp EXPERIMENTS.md
        echo "ok: EXPERIMENTS.md scalar-vs-SIMD table refreshed"
    else
        echo "WARN: bench-diff --markdown failed; SIMD table left as-is"
    fi
else
    echo "WARN: missing BENCH files or markers; SIMD table left as-is"
fi

# -- EXPERIMENTS.md §Long-context table: seq vs peak scratch ----------
note "EXPERIMENTS.md §Long-context table refresh"
if [ -s BENCH_sweep_long.json ] \
        && grep -q LONGCTX_TABLE_BEGIN EXPERIMENTS.md \
        && grep -q LONGCTX_TABLE_END EXPERIMENTS.md; then
    if cargo run --release --quiet -- longctx-gate \
            --report BENCH_sweep_long.json --markdown \
            > /tmp/topkima_longctx_table.md; then
        awk '
            /LONGCTX_TABLE_BEGIN/ {
                print
                while ((getline line < "/tmp/topkima_longctx_table.md") > 0)
                    print line
                skip = 1
                next
            }
            /LONGCTX_TABLE_END/ { skip = 0 }
            skip == 0 { print }
        ' EXPERIMENTS.md > EXPERIMENTS.md.tmp \
            && mv EXPERIMENTS.md.tmp EXPERIMENTS.md
        echo "ok: EXPERIMENTS.md §Long-context table refreshed"
    else
        echo "WARN: longctx-gate --markdown failed; table left as-is"
    fi
else
    echo "WARN: no BENCH_sweep_long.json or no markers; table left as-is"
fi

# -- EXPERIMENTS.md cross-accelerator Table 1: registry designs vs ----
# -- conv-SM at the paper's d=384, k=5, alpha=0.31 point           ----
note "EXPERIMENTS.md cross-accelerator Table 1 refresh"
if grep -q TABLE1_BEGIN EXPERIMENTS.md \
        && grep -q TABLE1_END EXPERIMENTS.md; then
    if cargo run --release --quiet -- accel-table --markdown \
            > /tmp/topkima_accel_table.md; then
        awk '
            /TABLE1_BEGIN/ {
                print
                while ((getline line < "/tmp/topkima_accel_table.md") > 0)
                    print line
                skip = 1
                next
            }
            /TABLE1_END/ { skip = 0 }
            skip == 0 { print }
        ' EXPERIMENTS.md > EXPERIMENTS.md.tmp \
            && mv EXPERIMENTS.md.tmp EXPERIMENTS.md
        echo "ok: EXPERIMENTS.md cross-accelerator Table 1 refreshed"
    else
        echo "WARN: accel-table --markdown failed; Table 1 left as-is"
    fi
else
    echo "WARN: no TABLE1 markers in EXPERIMENTS.md; Table 1 left as-is"
fi

if [ "$status" = "0" ]; then
    note "CI green"
else
    note "CI failed"
fi
exit "$status"
