"""Synthetic dataset generators: determinism, structure, learnability hooks."""

import numpy as np
import pytest

from compile import data as D


class TestSynthCifar:
    def test_shapes_and_dtypes(self):
        xs, ys = D.synth_cifar(10, 64, seed=0)
        assert xs.shape == (64, 32, 32, 3) and xs.dtype == np.float32
        assert ys.shape == (64,) and ys.dtype == np.int32

    def test_deterministic(self):
        a = D.synth_cifar(10, 16, seed=7)
        b = D.synth_cifar(10, 16, seed=7)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])

    def test_seed_changes_data(self):
        a, _ = D.synth_cifar(10, 16, seed=1)
        b, _ = D.synth_cifar(10, 16, seed=2)
        assert not np.array_equal(a, b)

    def test_labels_cover_range(self):
        _, ys = D.synth_cifar(10, 2000, seed=0)
        assert set(np.unique(ys)) == set(range(10))

    def test_100_classes(self):
        _, ys = D.synth_cifar(100, 3000, seed=0)
        assert ys.max() == 99 and ys.min() == 0

    def test_class_signal_exists(self):
        # same-class images correlate more than cross-class on average
        xs, ys = D.synth_cifar(4, 400, seed=3)
        protos = [xs[ys == c].mean(axis=0).ravel() for c in range(4)]
        # prototypes of distinct classes should be nearly orthogonal
        # relative to their own norms (random shifts wash phases, so just
        # demand within-class spread < cross-class distance on centroids)
        dists = [np.linalg.norm(protos[i] - protos[j])
                 for i in range(4) for j in range(i + 1, 4)]
        assert min(dists) > 0.05


class TestSynthSquad:
    def test_shapes(self):
        toks, spans = D.synth_squad(32, seed=0, seq_len=128)
        assert toks.shape == (32, 128) and spans.shape == (32, 2)

    def test_header_layout(self):
        toks, _ = D.synth_squad(16, seed=1)
        assert (toks[:, 0] == D.CLS).all()
        assert (toks[:, 3] == D.SEP).all()

    def test_answer_follows_query_bigram(self):
        toks, spans = D.synth_squad(64, seed=2, seq_len=96)
        for t, (s, e) in zip(toks, spans):
            q1, q2 = t[1], t[2]
            assert t[s - 2] == q1 and t[s - 1] == q2, "span preceded by bigram"
            assert t[e + 1] == D.END, "span terminated by END sentinel"
            assert s <= e < 96

    def test_bigram_unique_in_body(self):
        toks, spans = D.synth_squad(64, seed=3, seq_len=96)
        for t, (s, _) in zip(toks, spans):
            q1, q2 = int(t[1]), int(t[2])
            body = t[4:]
            hits = [i for i in range(len(body) - 1)
                    if body[i] == q1 and body[i + 1] == q2]
            assert len(hits) == 1, hits

    def test_deterministic(self):
        a = D.synth_squad(8, seed=9)
        b = D.synth_squad(8, seed=9)
        np.testing.assert_array_equal(a[0], b[0])
        np.testing.assert_array_equal(a[1], b[1])


class TestBatches:
    def test_batch_shapes_and_coverage(self):
        xs = np.arange(100)[:, None].astype(np.float32)
        ys = np.arange(100).astype(np.int32)
        gen = D.batches((xs, ys), 10, seed=0)
        xb, yb = next(gen)
        assert xb.shape == (10, 1) and yb.shape == (10,)

    def test_alignment_preserved(self):
        xs = np.arange(50).astype(np.float32)
        ys = np.arange(50).astype(np.int32)
        gen = D.batches((xs, ys), 8, seed=1)
        for _ in range(10):
            xb, yb = next(gen)
            np.testing.assert_array_equal(
                np.asarray(xb).astype(np.int32), np.asarray(yb))
