"""IMC Q·K^T Pallas kernel vs oracle + hardware-grid invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant
from compile.kernels import ref
from compile.kernels.imc_qkt import calibrate, imc_qkt

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


@pytest.fixture()
def calib():
    q = rand((64, 32), seed=1)
    kt = rand((32, 96), seed=2)
    return q, kt, calibrate(q, kt)


class TestImcQkt:
    def test_matches_ref(self, calib):
        q, kt, c = calib
        got = imc_qkt(q, kt, **c)
        want = ref.imc_qkt_ref(q, kt, q_scale=c["q_scale"],
                               w_scale=c["w_scale"],
                               adc_full_scale=c["adc_full_scale"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    def test_block_invariance(self, calib):
        q, kt, c = calib
        a = imc_qkt(q, kt, row_block=8, col_block=32, **c)
        b = imc_qkt(q, kt, row_block=64, col_block=96, **c)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_outputs_on_adc_grid(self, calib):
        q, kt, c = calib
        out = np.asarray(imc_qkt(q, kt, **c))
        lsb = c["adc_full_scale"] / (2 ** (quant.N_BITS_ADC - 1) - 1)
        codes = out / lsb
        np.testing.assert_allclose(codes, np.round(codes), atol=1e-4)

    def test_quantization_error_small_vs_fp(self, calib):
        # the whole premise of QAT: the quantized macro tracks FP matmul
        q, kt, c = calib
        got = np.asarray(imc_qkt(q, kt, **c))
        fp = np.asarray(q @ kt)
        rel = np.abs(got - fp).mean() / np.abs(fp).mean()
        assert rel < 0.25, rel

    def test_nonsquare_padding(self):
        q = rand((7, 16), seed=3)
        kt = rand((16, 33), seed=4)
        c = calibrate(q, kt)
        got = imc_qkt(q, kt, **c)
        assert got.shape == (7, 33)
        want = ref.imc_qkt_ref(q, kt, q_scale=c["q_scale"],
                               w_scale=c["w_scale"],
                               adc_full_scale=c["adc_full_scale"])
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    @settings(max_examples=6, deadline=None)
    @given(m=st.integers(1, 20), d=st.integers(2, 48), n=st.integers(1, 70),
           seed=st.integers(0, 2 ** 16))
    def test_hypothesis_shapes(self, m, d, n, seed):
        q = rand((m, d), seed=seed)
        kt = rand((d, n), seed=seed + 1)
        c = calibrate(q, kt)
        got = np.asarray(imc_qkt(q, kt, **c))
        want = np.asarray(ref.imc_qkt_ref(
            q, kt, q_scale=c["q_scale"], w_scale=c["w_scale"],
            adc_full_scale=c["adc_full_scale"]))
        # MACs landing exactly on an ADC decision boundary may round to
        # adjacent codes depending on f32 accumulation order (pallas
        # tiles vs single matmul) — allow a one-LSB disagreement there.
        lsb = c["adc_full_scale"] / 15.0
        diff = np.abs(got - want)
        assert (diff <= lsb * 1.001).all(), diff.max()
        # and at most a tiny fraction of entries may sit on a boundary
        assert (diff > lsb * 0.5).mean() < 0.05


class TestCalibrate:
    def test_scales_positive(self, calib):
        _, _, c = calib
        assert c["q_scale"] > 0 and c["w_scale"] > 0
        assert c["adc_full_scale"] > 0

    def test_deterministic(self):
        q, kt = rand((8, 8), seed=5), rand((8, 8), seed=6)
        assert calibrate(q, kt) == calibrate(q, kt)
