"""Fused topkima attention Pallas kernel vs oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import topkima_attention
from compile.kernels.imc_qkt import calibrate
from compile.kernels.topk_softmax import crossbar_split

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0):
    return jax.random.normal(jax.random.PRNGKey(seed), shape)


def head_inputs(sl=64, d_k=32, d_v=32, seed=0):
    return (rand((sl, d_k), seed=seed), rand((d_k, sl), seed=seed + 1),
            rand((sl, d_v), seed=seed + 2))


class TestTopkimaAttention:
    @pytest.mark.parametrize("k", [1, 5, 10])
    def test_matches_ref(self, k):
        q, kt, v = head_inputs()
        got = topkima_attention(q, kt, v, k)
        want = ref.attention_ref(q, kt, v, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)

    def test_row_block_invariance(self):
        q, kt, v = head_inputs(sl=50, seed=3)
        a = topkima_attention(q, kt, v, 5, row_block=7)
        b = topkima_attention(q, kt, v, 5, row_block=32)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)

    def test_sub_topk_variant(self):
        q, kt, v = head_inputs(sl=96, seed=4)
        segs, ks = crossbar_split(96, 5, 40)
        got = topkima_attention(q, kt, v, 5, segments=segs, ks=ks)
        a = ref.sub_topk_softmax_ref(q @ kt, segs, ks)
        want = a @ v
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)

    def test_output_in_value_convex_hull(self):
        # attention output rows are convex combos of V rows
        q, kt, v = head_inputs(seed=5)
        out = np.asarray(topkima_attention(q, kt, v, 5))
        vn = np.asarray(v)
        assert out.min() >= vn.min() - 1e-5
        assert out.max() <= vn.max() + 1e-5

    def test_k1_copies_argmax_value_row(self):
        q, kt, v = head_inputs(seed=6)
        out = np.asarray(topkima_attention(q, kt, v, 1))
        winners = np.argmax(np.asarray(q @ kt), axis=-1)
        np.testing.assert_allclose(out, np.asarray(v)[winners], rtol=1e-5)

    def test_quantized_path_close_to_fp(self):
        q, kt, v = head_inputs(seed=7)
        c = calibrate(q, kt)
        qz = topkima_attention(q, kt, v, 5, quantized=True,
                               q_scale=c["q_scale"], w_scale=c["w_scale"],
                               adc_full_scale=c["adc_full_scale"])
        fp = topkima_attention(q, kt, v, 5)
        # winners may shift on near-ties; demand coarse agreement only
        err = np.abs(np.asarray(qz) - np.asarray(fp)).mean()
        assert err < 0.6 * np.abs(np.asarray(fp)).mean() + 0.15

    @settings(max_examples=5, deadline=None)
    @given(sl=st.integers(4, 64), d=st.integers(2, 32),
           k=st.integers(1, 6), seed=st.integers(0, 2 ** 16))
    def test_hypothesis_sweep(self, sl, d, k, seed):
        k = min(k, sl)
        q, kt, v = head_inputs(sl=sl, d_k=d, d_v=d, seed=seed)
        got = topkima_attention(q, kt, v, k)
        want = ref.attention_ref(q, kt, v, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)
