"""L2 model tests: shapes, TFCBP semantics, scale-free folding, QAT."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

VIT = dataclasses.replace(M.VIT_TINY, d_model=32, n_heads=2, n_layers=2,
                          d_ff=64, image_size=16, patch_size=4)
BERT = dataclasses.replace(M.BERT_TINY, d_model=32, n_heads=2, n_layers=2,
                           d_ff=64, seq_len=32, vocab_size=16)


@pytest.fixture(scope="module")
def vit_params():
    return M.init_params(jax.random.PRNGKey(0), VIT)


@pytest.fixture(scope="module")
def bert_params():
    return M.init_params(jax.random.PRNGKey(1), BERT)


class TestShapes:
    def test_vit_logits(self, vit_params):
        x = jax.random.normal(jax.random.PRNGKey(2), (3, 16, 16, 3))
        out = M.forward(vit_params, VIT, x)
        assert out.shape == (3, VIT.n_classes)

    def test_bert_span_logits(self, bert_params):
        toks = jax.random.randint(jax.random.PRNGKey(3), (3, 32), 0, 16)
        out = M.forward(bert_params, BERT, toks)
        assert out.shape == (3, 32, 2)

    def test_tokens_property(self):
        assert VIT.tokens == (16 // 4) ** 2 + 1
        assert BERT.tokens == 32

    def test_param_count_nonzero(self, vit_params):
        assert M.count_params(vit_params) > 10_000


class TestTFCBP:
    def test_forward_is_topk(self):
        x = jax.random.normal(jax.random.PRNGKey(4), (2, 3, 20))
        y = M.tfcbp_softmax(x, 4)
        want = ref.topk_softmax_ref(x, 4)
        np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                                   rtol=1e-6)

    def test_backward_is_full_softmax_grad(self):
        x = jax.random.normal(jax.random.PRNGKey(5), (4, 16))

        def loss_topk(x):
            return jnp.sum(M.tfcbp_softmax(x, 3) * jnp.arange(16.0))

        def loss_full(x):
            return jnp.sum(jax.nn.softmax(x, -1) * jnp.arange(16.0))

        g_topk = jax.grad(loss_topk)(x)
        g_full = jax.grad(loss_full)(x)
        np.testing.assert_allclose(np.asarray(g_topk), np.asarray(g_full),
                                   rtol=1e-5, atol=1e-6)

    def test_k0_is_dense_softmax(self):
        x = jax.random.normal(jax.random.PRNGKey(6), (4, 16))
        np.testing.assert_allclose(
            np.asarray(M.tfcbp_softmax(x, 0)),
            np.asarray(jax.nn.softmax(x, -1)), rtol=1e-6)

    def test_grad_nonzero_outside_topk(self):
        # TFCBP's point: losers still receive gradient signal
        x = jnp.array([[5.0, 4.0, 0.0, -1.0]])
        g = jax.grad(lambda v: M.tfcbp_softmax(v, 1)[0, 0])(x)
        assert float(jnp.abs(g[0, 2])) > 0
        assert float(jnp.abs(g[0, 3])) > 0


class TestScaleFree:
    def test_fold_preserves_logits(self, vit_params):
        x = jax.random.normal(jax.random.PRNGKey(7), (2, 16, 16, 3))
        base = M.forward(vit_params, VIT, x)
        folded = M.fold_scale_free(vit_params, VIT)
        out = M.forward(folded, VIT, x, fold_scale=True)
        np.testing.assert_allclose(np.asarray(base), np.asarray(out),
                                   rtol=2e-4, atol=2e-4)

    def test_fold_changes_wq_only(self, vit_params):
        folded = M.fold_scale_free(vit_params, VIT)
        for orig, fl in zip(vit_params["layers"], folded["layers"]):
            scale = 1.0 / np.sqrt(VIT.d_head)
            np.testing.assert_allclose(np.asarray(fl["wq"]["w"]),
                                       np.asarray(orig["wq"]["w"]) * scale,
                                       rtol=1e-6)
            np.testing.assert_allclose(np.asarray(fl["wk"]["w"]),
                                       np.asarray(orig["wk"]["w"]))


class TestQAT:
    def test_qat_forward_finite_and_close(self, vit_params):
        x = jax.random.normal(jax.random.PRNGKey(8), (2, 16, 16, 3))
        qcfg = dataclasses.replace(VIT, qat=True)
        out = M.forward(vit_params, qcfg, x)
        assert np.isfinite(np.asarray(out)).all()
        base = M.forward(vit_params, VIT, x)
        # fake-quant perturbs but should not destroy the logits
        corr = np.corrcoef(np.asarray(out).ravel(),
                           np.asarray(base).ravel())[0, 1]
        assert corr > 0.7, corr

    def test_qat_grad_flows(self, vit_params):
        x = jax.random.normal(jax.random.PRNGKey(9), (2, 16, 16, 3))
        qcfg = dataclasses.replace(VIT, qat=True)
        g = jax.grad(M.vit_loss)(vit_params, qcfg, x, jnp.array([0, 1]))
        total = sum(float(jnp.abs(t).sum())
                    for t in jax.tree_util.tree_leaves(g))
        assert total > 0


class TestLosses:
    def test_vit_loss_decreases_on_true_label_logit(self, vit_params):
        x = jax.random.normal(jax.random.PRNGKey(10), (4, 16, 16, 3))
        y = jnp.array([0, 1, 2, 3])
        l0 = float(M.vit_loss(vit_params, VIT, x, y))
        assert l0 > 0

    def test_bert_em_bounds(self, bert_params):
        toks = jax.random.randint(jax.random.PRNGKey(11), (4, 32), 0, 16)
        spans = jnp.array([[1, 2], [3, 4], [5, 6], [7, 8]])
        em = float(M.bert_exact_match(bert_params, BERT, toks, spans))
        assert 0.0 <= em <= 1.0

    def test_pallas_path_matches_jnp_path(self, vit_params):
        x = jax.random.normal(jax.random.PRNGKey(12), (1, 16, 16, 3))
        a = M.forward(vit_params, VIT, x, use_pallas=False)
        b = M.forward(vit_params, VIT, x, use_pallas=True)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)
