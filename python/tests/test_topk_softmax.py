"""Pallas topk_softmax kernel vs pure-jnp oracle (the core L1 signal)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.topk_softmax import (
    crossbar_split, sub_topk_softmax, topk_softmax)

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(seed), shape, dtype=dtype)


class TestTopkMask:
    def test_matches_lax_topk(self):
        x = rand((32, 64))
        np.testing.assert_array_equal(
            np.asarray(ref.topk_mask_ref(x, 5)),
            np.asarray(ref.topk_mask_lax(x, 5)))

    def test_matches_lax_topk_with_ties(self):
        x = jnp.round(rand((32, 64), seed=1) * 2) / 2
        np.testing.assert_array_equal(
            np.asarray(ref.topk_mask_ref(x, 7)),
            np.asarray(ref.topk_mask_lax(x, 7)))

    def test_tie_prefers_smaller_index(self):
        # all-equal row: the arbiter grants smaller column addresses first
        x = jnp.zeros((1, 10))
        mask = np.asarray(ref.topk_mask_ref(x, 3))[0]
        assert mask.tolist() == [True] * 3 + [False] * 7

    def test_exactly_k_selected(self):
        x = rand((16, 40), seed=2)
        for k in (1, 3, 17):
            mask = np.asarray(ref.topk_mask_ref(x, k))
            assert (mask.sum(axis=-1) == k).all()

    def test_k_geq_d_selects_all(self):
        x = rand((4, 8))
        assert np.asarray(ref.topk_mask_ref(x, 8)).all()
        assert np.asarray(ref.topk_mask_ref(x, 100)).all()


class TestTopkSoftmaxKernel:
    @pytest.mark.parametrize("k", [1, 2, 5, 10])
    @pytest.mark.parametrize("shape", [(4, 64), (2, 3, 384), (1, 17)])
    def test_matches_ref(self, k, shape):
        if k >= shape[-1]:
            pytest.skip("k >= d")
        x = rand(shape, seed=k)
        got = topk_softmax(x, k)
        want = ref.topk_softmax_ref(x, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)

    def test_rows_sum_to_one(self):
        y = np.asarray(topk_softmax(rand((8, 128)), 5))
        np.testing.assert_allclose(y.sum(axis=-1), 1.0, rtol=1e-5)

    def test_nonselected_exactly_zero(self):
        x = rand((8, 128), seed=3)
        y = np.asarray(topk_softmax(x, 5))
        assert ((y > 0).sum(axis=-1) == 5).all()

    def test_full_k_equals_softmax(self):
        x = rand((8, 32), seed=4)
        np.testing.assert_allclose(
            np.asarray(topk_softmax(x, 32)),
            np.asarray(ref.softmax_ref(x)), rtol=1e-6, atol=1e-7)

    def test_row_block_invariance(self):
        x = rand((13, 96), seed=5)
        a = topk_softmax(x, 5, row_block=1)
        b = topk_softmax(x, 5, row_block=8)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-7)

    @settings(max_examples=8, deadline=None)
    @given(rows=st.integers(1, 9), d=st.integers(2, 80),
           k=st.integers(1, 12), seed=st.integers(0, 2 ** 16))
    def test_hypothesis_sweep(self, rows, d, k, seed):
        k = min(k, d)
        x = rand((rows, d), seed=seed)
        got = topk_softmax(x, k)
        want = ref.topk_softmax_ref(x, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


class TestSubTopk:
    def test_matches_ref(self):
        x = rand((6, 384), seed=6)
        segs, ks = crossbar_split(384, 5, 256)
        got = sub_topk_softmax(x, segs, ks)
        want = ref.sub_topk_softmax_ref(x, segs, ks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)

    def test_paper_splits(self):
        # Sec. IV-B: d=384 → 256-wide: (3,2); 128-wide: (2,2,1)
        assert crossbar_split(384, 5, 256) == ((256, 128), (3, 2))
        assert crossbar_split(384, 5, 128) == ((128, 128, 128), (2, 2, 1))

    def test_paper_example_selection(self):
        # Sec. IV: QK^T = [1..384], 128-wide xbars, k=5 → selected values
        # [127,128], [255,256], [384]
        x = jnp.arange(1.0, 385.0)[None, :]
        segs, ks = crossbar_split(384, 5, 128)
        mask = np.asarray(ref.sub_topk_mask_ref(x, segs, ks))[0]
        sel = (np.arange(1, 385))[mask]
        assert sel.tolist() == [127, 128, 255, 256, 384]

    def test_single_segment_equals_global(self):
        x = rand((4, 100), seed=7)
        got = sub_topk_softmax(x, (100,), (5,))
        want = ref.topk_softmax_ref(x, 5)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-7)

    def test_sum_ki_probability_one(self):
        x = rand((4, 300), seed=8)
        y = np.asarray(sub_topk_softmax(x, (128, 128, 44), (2, 2, 1)))
        np.testing.assert_allclose(y.sum(axis=-1), 1.0, rtol=1e-5)
        assert ((y > 0).sum(axis=-1) == 5).all()

    @settings(max_examples=6, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), d=st.integers(20, 200),
           k=st.integers(2, 8), width=st.integers(8, 128))
    def test_hypothesis_sub_topk(self, seed, d, k, width):
        segs, ks = crossbar_split(d, k, width)
        if any(ki > s for s, ki in zip(segs, ks)):
            return
        x = rand((3, d), seed=seed)
        got = sub_topk_softmax(x, segs, ks)
        want = ref.sub_topk_softmax_ref(x, segs, ks)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5, atol=1e-6)


class TestCrossbarSplit:
    def test_k_conserved(self):
        for d, k, w in [(384, 5, 256), (384, 5, 128), (100, 7, 30),
                        (64, 1, 16), (4096, 5, 256)]:
            segs, ks = crossbar_split(d, k, w)
            assert sum(segs) == d
            assert sum(ks) == k
            assert all(s > 0 for s in segs)
            assert all(ki >= 0 for ki in ks)

    def test_each_xbar_wins_when_k_allows(self):
        segs, ks = crossbar_split(384, 5, 128)
        assert all(ki >= 1 for ki in ks)
