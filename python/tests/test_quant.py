"""Quantization contract tests (shared numerical grid with the rust side)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import quant

jax.config.update("jax_platform_name", "cpu")


def rand(shape, seed=0, scale=1.0):
    return scale * jax.random.normal(jax.random.PRNGKey(seed), shape)


class TestFakeQuant:
    def test_idempotent(self):
        x = rand((32,), seed=1)
        s = quant.symmetric_scale(x, 5)
        q1 = quant.fake_quant(x, 5, scale=s)
        q2 = quant.fake_quant(q1, 5, scale=s)
        np.testing.assert_allclose(np.asarray(q1), np.asarray(q2), rtol=1e-6)

    def test_error_bounded_by_half_lsb(self):
        x = rand((256,), seed=2)
        s = float(quant.symmetric_scale(x, 5))
        q = quant.fake_quant(x, 5, scale=s)
        err = np.abs(np.asarray(q - x))
        inside = np.abs(np.asarray(x)) <= 15 * s
        assert (err[inside] <= s / 2 + 1e-6).all()

    def test_ste_gradient_is_identity(self):
        x = rand((16,), seed=3)
        g = jax.grad(lambda v: jnp.sum(quant.fake_quant(v, 5, scale=0.1)))(x)
        clipped = np.abs(np.asarray(x) / 0.1) <= 15
        np.testing.assert_allclose(np.asarray(g)[clipped], 1.0)

    def test_codes_in_range(self):
        x = rand((1024,), seed=4, scale=10)
        codes = np.asarray(quant.quantize_codes(x, 5, scale=0.3))
        assert codes.min() >= -15 and codes.max() <= 15

    @settings(max_examples=12, deadline=None)
    @given(seed=st.integers(0, 2 ** 16), n_bits=st.integers(2, 8))
    def test_hypothesis_levels(self, seed, n_bits):
        x = rand((128,), seed=seed, scale=3.0)
        s = float(quant.symmetric_scale(x, n_bits))
        q = np.asarray(quant.fake_quant(x, n_bits, scale=s))
        levels = np.unique(np.round(q / s).astype(int))
        qmax = 2 ** (n_bits - 1) - 1
        assert levels.min() >= -qmax and levels.max() <= qmax


class TestTernaryCells:
    def test_grid_is_15_levels(self):
        x = jnp.linspace(-2, 2, 1001)
        s = 2.0 / 7
        q = np.asarray(quant.quantize_ternary_cells(x, scale=s))
        codes = np.unique(np.round(q / s).astype(int))
        assert codes.min() == -7 and codes.max() == 7
        assert len(codes) == 15

    def test_pack_unpack_roundtrip(self):
        codes = jnp.arange(-7, 8, dtype=jnp.int32)
        cells = quant.pack_ternary_cells(codes)
        assert np.asarray(jnp.abs(cells) <= 1).all()
        back = quant.unpack_ternary_cells(cells)
        np.testing.assert_array_equal(np.asarray(back), np.asarray(codes))

    def test_cells_are_ternary(self):
        codes = jnp.array([-7, -3, 0, 5, 7])
        cells = np.asarray(quant.pack_ternary_cells(codes))
        assert set(np.unique(cells)).issubset({-1, 0, 1})

    def test_cell_scales_binary(self):
        # 3 cells scaled 1/2/4 span exactly -7..7 (Sec. III-A)
        assert quant.CELL_SCALES == (1, 2, 4)
        assert quant.WEIGHT_LEVELS == 7


class TestAdc:
    def test_transfer_monotonic(self):
        v = jnp.linspace(-1, 1, 201)
        q = np.asarray(quant.adc_quantize(v, 1.0))
        assert (np.diff(q) >= -1e-9).all()

    def test_codes_range_5bit(self):
        v = rand((512,), seed=5, scale=2.0)
        codes = np.asarray(quant.adc_codes(v, 1.0, n_bits=5))
        assert codes.min() >= -16 and codes.max() <= 15

    def test_full_scale_hits_top_code(self):
        codes = quant.adc_codes(jnp.array([1.0, -1.0]), 1.0, n_bits=5)
        assert codes[0] == 15 and codes[1] == -15

    @pytest.mark.parametrize("n_bits", [3, 5, 8])
    def test_quantize_matches_codes(self, n_bits):
        v = rand((64,), seed=6)
        fs = 1.5
        lsb = fs / (2 ** (n_bits - 1) - 1)
        q = np.asarray(quant.adc_quantize(v, fs, n_bits=n_bits))
        c = np.asarray(quant.adc_codes(v, fs, n_bits=n_bits))
        np.testing.assert_allclose(q, c * lsb, rtol=1e-6)
