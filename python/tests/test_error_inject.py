"""Error-injection pipeline tests (Fig 4b SW side)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import error_inject as EI
from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = dataclasses.replace(M.VIT_TINY, d_model=32, n_heads=2, n_layers=2,
                          d_ff=64, image_size=16, patch_size=4, topk=5)


@pytest.fixture(scope="module")
def setup():
    params = M.init_params(jax.random.PRNGKey(0), CFG)
    xs = jax.random.normal(jax.random.PRNGKey(1), (8, 16, 16, 3))
    return params, xs


class TestErrorModel:
    def test_zero_model_is_exact(self, setup):
        params, xs = setup
        em = EI.ErrorModel(0.0, 0.0, 0.0)
        noisy = EI.attention_with_ima_error(
            params, CFG, xs, jax.random.PRNGKey(2), em)
        clean = M.forward(params, CFG, xs)
        np.testing.assert_allclose(np.asarray(noisy), np.asarray(clean),
                                   rtol=1e-4, atol=1e-4)

    def test_noise_perturbs_but_bounded(self, setup):
        params, xs = setup
        em = EI.ErrorModel()
        noisy = EI.attention_with_ima_error(
            params, CFG, xs, jax.random.PRNGKey(3), em)
        clean = M.forward(params, CFG, xs)
        diff = np.abs(np.asarray(noisy) - np.asarray(clean))
        assert diff.max() > 0, "error model had no effect"
        # correlation stays high: the error is LSB-scale, not destructive
        corr = np.corrcoef(np.asarray(noisy).ravel(),
                           np.asarray(clean).ravel())[0, 1]
        assert corr > 0.8, corr

    def test_error_sampling_statistics(self):
        em = EI.ErrorModel(sigma_noise=0.5, sigma_offset=0.0, p_skip=0.0)
        err = EI.ima_error_model(jax.random.PRNGKey(4), (200, 64), em, 1.0)
        e = np.asarray(err)
        assert abs(e.mean()) < 0.05
        assert abs(e.std() - 0.5) < 0.05

    def test_column_offset_is_static_per_column(self):
        em = EI.ErrorModel(sigma_noise=0.0, sigma_offset=0.5, p_skip=0.0)
        err = np.asarray(EI.ima_error_model(
            jax.random.PRNGKey(5), (100, 16), em, 1.0))
        # same offset down each column → zero variance within a column
        assert np.allclose(err.std(axis=0), 0.0, atol=1e-6)
        assert err.std() > 0.1

    def test_eval_with_error_bounds(self, setup):
        params, _ = setup
        from compile import train as T
        _, eval_set = T.make_dataset(CFG, 64, 64, seed=0)
        acc = EI.eval_with_error(params, CFG, eval_set, EI.ErrorModel(),
                                 batch_size=32)
        assert 0.0 <= acc <= 1.0
