"""Training loop for the synthetic Topkima-Former models (build time only).

Implements the paper's training recipe (Sec. III-B):

* **TFCBP** — top-k forward / complete backward, already inside
  ``model.tfcbp_softmax``; enabled whenever ``cfg.topk > 0``.
* **QAT** — 5-bit activation / ternary-cell weight fake-quant with STE,
  enabled by ``cfg.qat``; FP32 master weights are updated in backward.

A small hand-rolled Adam (no optax in this environment) trains ViT-tiny on
synth-CIFAR and BERT-tiny on synth-SQuAD. ``train_model`` is the single
entry point used by the Fig 3 sweep (``experiments.py``) and by ``aot.py``
to produce deployable checkpoints.
"""

from __future__ import annotations

import dataclasses
import functools
import pickle
from pathlib import Path
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import data as D
from . import model as M


# ---------------------------------------------------------------------------
# Minimal Adam
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AdamState:
    step: int
    mu: M.Params
    nu: M.Params


def adam_init(params: M.Params) -> AdamState:
    zeros = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(step=0,
                     mu=zeros,
                     nu=jax.tree_util.tree_map(jnp.zeros_like, params))


def adam_update(state: AdamState, grads: M.Params, params: M.Params,
                lr: float, b1=0.9, b2=0.999, eps=1e-8
                ) -> Tuple[AdamState, M.Params]:
    step = state.step + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g,
                                state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g,
                                state.nu, grads)
    mhat_scale = 1.0 / (1 - b1 ** step)
    vhat_scale = 1.0 / (1 - b2 ** step)
    new_params = jax.tree_util.tree_map(
        lambda p, m, v: p - lr * (m * mhat_scale) /
        (jnp.sqrt(v * vhat_scale) + eps),
        params, mu, nu)
    return AdamState(step=step, mu=mu, nu=nu), new_params


# ---------------------------------------------------------------------------
# Training
# ---------------------------------------------------------------------------

def _loss_fn(cfg: M.ModelConfig) -> Callable:
    return M.vit_loss if cfg.kind == "vit" else M.bert_span_loss


def _metric_fn(cfg: M.ModelConfig) -> Callable:
    return M.vit_accuracy if cfg.kind == "vit" else M.bert_exact_match


def make_dataset(cfg: M.ModelConfig, n_train: int, n_eval: int, seed: int):
    """(train arrays, eval arrays) for the config's task."""
    if cfg.kind == "vit":
        xs, ys = D.synth_cifar(cfg.n_classes, n_train + n_eval, seed=seed,
                               image_size=cfg.image_size)
    else:
        xs, ys = D.synth_squad(n_train + n_eval, seed=seed,
                               seq_len=cfg.seq_len, vocab_size=cfg.vocab_size)
    return (xs[:n_train], ys[:n_train]), (xs[n_train:], ys[n_train:])


def evaluate(params: M.Params, cfg: M.ModelConfig, eval_set,
             batch_size: int = 100, **fw) -> float:
    """Mean accuracy / exact-match over the eval split."""
    xs, ys = eval_set
    metric = _metric_fn(cfg)
    fn = jax.jit(functools.partial(metric, cfg=cfg, **fw),
                 static_argnames=())
    total, n = 0.0, 0
    for i in range(0, len(xs), batch_size):
        xb = jnp.asarray(xs[i:i + batch_size])
        yb = jnp.asarray(ys[i:i + batch_size])
        total += float(metric(params, cfg, xb, yb, **fw)) * len(xb)
        n += len(xb)
    return total / max(n, 1)


def train_model(cfg: M.ModelConfig, *, steps: int = 600,
                batch_size: int = 64, lr: float = 1e-3, seed: int = 0,
                n_train: int = 4096, n_eval: int = 1024,
                init: Optional[M.Params] = None,
                log_every: int = 0) -> Dict:
    """Train one model; returns dict with params, eval accuracy, history.

    ``init`` warm-starts from existing params (used by the Fig 3 sweep to
    fine-tune per-k from a full-softmax pretrain, which is how TFCBP is
    deployed: take a trained network, re-train briefly with top-k
    forward).
    """
    train_set, eval_set = make_dataset(cfg, n_train, n_eval, seed)
    params = init if init is not None else M.init_params(
        jax.random.PRNGKey(seed), cfg)
    opt = adam_init(params)
    loss_fn = _loss_fn(cfg)

    @jax.jit
    def step_fn(params, opt_mu, opt_nu, opt_step, xb, yb):
        loss, grads = jax.value_and_grad(loss_fn)(params, cfg, xb, yb)
        state = AdamState(step=opt_step, mu=opt_mu, nu=opt_nu)
        state, params = adam_update(state, grads, params, lr)
        return params, state.mu, state.nu, state.step, loss

    history = []
    gen = D.batches(train_set, batch_size, seed=seed)
    for i in range(steps):
        xb, yb = next(gen)
        params, opt.mu, opt.nu, opt.step, loss = step_fn(
            params, opt.mu, opt.nu, opt.step, xb, yb)
        if log_every and (i % log_every == 0 or i == steps - 1):
            history.append((i, float(loss)))
            print(f"  step {i:5d} loss {float(loss):.4f}")

    acc = evaluate(params, cfg, eval_set)
    return {"params": params, "cfg": cfg, "accuracy": acc,
            "history": history, "eval_set": eval_set}


# ---------------------------------------------------------------------------
# Checkpoint I/O (numpy pickle — consumed by aot.py, and exported to the
# rust side as raw .npz where needed)
# ---------------------------------------------------------------------------

def save_checkpoint(path: str | Path, params: M.Params,
                    cfg: M.ModelConfig, meta: Optional[dict] = None) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    blob = {
        "params": jax.tree_util.tree_map(np.asarray, params),
        "cfg": dataclasses.asdict(cfg),
        "meta": meta or {},
    }
    with open(path, "wb") as f:
        pickle.dump(blob, f)


def load_checkpoint(path: str | Path) -> Tuple[M.Params, M.ModelConfig, dict]:
    with open(path, "rb") as f:
        blob = pickle.load(f)
    params = jax.tree_util.tree_map(jnp.asarray, blob["params"])
    cfg = M.ModelConfig(**blob["cfg"])
    return params, cfg, blob["meta"]
