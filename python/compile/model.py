"""L2: the Topkima-Former transformer in JAX (build-time only).

Pure-functional transformer encoder used for both evaluation model
families of the paper:

* **ViT-tiny** — patch embedding + class token + classification head
  (the paper's ViT on CIFAR-10/100, scaled to the synthetic task);
* **BERT-tiny** — token+position embedding + span-extraction head
  (the paper's BERT-base/DistilBERT on SQuAD, scaled).

Paper features implemented here:

* **Scale-free attention** (Sec. III-C): `W_Q` is divided by `sqrt(d_k)`
  once at fold time (:func:`fold_scale_free`), so the attention kernel
  performs no per-element scaling. Training keeps the conventional
  parameterization; folding is a deploy-time rewrite, exactly as in HW.
* **TFCBP** (Sec. III-B): :func:`tfcbp_softmax` — top-k masked softmax in
  the forward pass, *complete* (all-d) softmax gradient in the backward
  pass, via ``jax.custom_vjp``.
* **QAT** (Sec. III-B): activations fake-quantized to 5 bits and attention
  weights (`K^T` path) to the 15-level ternary-cell grid with STE
  gradients; FP32 master weights are updated in backward.

The attention hot-spot calls the L1 Pallas kernels when ``use_pallas`` is
set (the AOT path), and the mathematically identical jnp reference during
training (pallas interpret mode is too slow to train through).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import quant
from .kernels import ref
from .kernels.attention import topkima_attention
from .kernels.topk_softmax import crossbar_split, topk_softmax

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Architecture + topkima hyper-parameters for one model variant."""

    kind: str = "vit"            # "vit" | "bert"
    d_model: int = 128
    n_heads: int = 4
    n_layers: int = 4
    d_ff: int = 256
    # topkima
    topk: int = 5                # k winners per softmax row; 0 = full softmax
    crossbar_cols: int = 0       # >0 enables sub-top-k with this crossbar width
    # QAT
    qat: bool = False            # fake-quant activations/weights on the IMC paths
    # vit
    image_size: int = 32
    patch_size: int = 4
    n_classes: int = 10
    # bert
    vocab_size: int = 64
    seq_len: int = 128

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    @property
    def n_patches(self) -> int:
        return (self.image_size // self.patch_size) ** 2

    @property
    def tokens(self) -> int:
        """Sequence length seen by the encoder (incl. cls token for ViT)."""
        return self.n_patches + 1 if self.kind == "vit" else self.seq_len

    def sub_topk(self) -> Tuple[Optional[tuple], Optional[tuple]]:
        """(segments, ks) for the configured crossbar width, or (None, None)."""
        if self.crossbar_cols and 0 < self.crossbar_cols < self.tokens:
            return crossbar_split(self.tokens, self.topk, self.crossbar_cols)
        return None, None


# Paper-shaped configs for the rust-side workload descriptors; the trained
# synthetic models use smaller instances of the same families.
VIT_TINY = ModelConfig(kind="vit", d_model=128, n_heads=4, n_layers=4,
                       d_ff=256, topk=5, image_size=32, patch_size=4,
                       n_classes=10)
BERT_TINY = ModelConfig(kind="bert", d_model=128, n_heads=4, n_layers=4,
                        d_ff=256, topk=5, vocab_size=64, seq_len=128)


# ---------------------------------------------------------------------------
# TFCBP: top-k forward, complete backward propagation (Sec. III-B)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(1, 2, 3))
def tfcbp_softmax(x: jnp.ndarray, k: int,
                  segments: Optional[tuple] = None,
                  ks: Optional[tuple] = None) -> jnp.ndarray:
    """Top-k softmax forward / full-softmax backward.

    Forward: softmax over the k largest logits per row (optionally with
    per-crossbar sub-top-k), zeros elsewhere — exactly what the topkima
    hardware produces. Backward: the gradient of the *complete* softmax
    at the same logits, so all d activations shape the update (TFCBP).
    """
    if k <= 0 or k >= x.shape[-1]:
        return jax.nn.softmax(x, axis=-1)
    if segments is not None:
        return ref.sub_topk_softmax_ref(x, segments, ks)
    return ref.topk_softmax_ref(x, k)


def _tfcbp_fwd(x, k, segments, ks):
    y = tfcbp_softmax(x, k, segments, ks)
    # Residual is the FULL softmax: the backward pass pretends the forward
    # was dense, which is what lets tiny k train without collapsing.
    s = jax.nn.softmax(x, axis=-1)
    return y, s


def _tfcbp_bwd(k, segments, ks, s, g):
    # d/dx softmax: s * (g - sum(g * s))
    dot = jnp.sum(g * s, axis=-1, keepdims=True)
    return (s * (g - dot),)


tfcbp_softmax.defvjp(_tfcbp_fwd, _tfcbp_bwd)


# ---------------------------------------------------------------------------
# Parameter init
# ---------------------------------------------------------------------------

def _dense_init(key, d_in, d_out):
    w = jax.random.normal(key, (d_in, d_out)) * (d_in ** -0.5)
    return {"w": w.astype(jnp.float32),
            "b": jnp.zeros((d_out,), jnp.float32)}


def _layer_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    d = cfg.d_model
    return {
        "wq": _dense_init(ks[0], d, d),
        "wk": _dense_init(ks[1], d, d),
        "wv": _dense_init(ks[2], d, d),
        "wo": _dense_init(ks[3], d, d),
        "ff1": _dense_init(ks[4], d, cfg.d_ff),
        "ff2": _dense_init(ks[5], cfg.d_ff, d),
        "ln1": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
        "ln2": {"g": jnp.ones((d,)), "b": jnp.zeros((d,))},
    }


def init_params(key, cfg: ModelConfig) -> Params:
    """Initialize the full parameter pytree for a model config."""
    keys = jax.random.split(key, cfg.n_layers + 4)
    params: Params = {
        "layers": [_layer_init(keys[i], cfg) for i in range(cfg.n_layers)],
        "ln_f": {"g": jnp.ones((cfg.d_model,)), "b": jnp.zeros((cfg.d_model,))},
    }
    if cfg.kind == "vit":
        patch_dim = 3 * cfg.patch_size ** 2
        params["patch"] = _dense_init(keys[-1], patch_dim, cfg.d_model)
        params["cls"] = jax.random.normal(keys[-2], (1, 1, cfg.d_model)) * 0.02
        params["pos"] = jax.random.normal(
            keys[-3], (1, cfg.n_patches + 1, cfg.d_model)) * 0.02
        params["head"] = _dense_init(keys[-4], cfg.d_model, cfg.n_classes)
    elif cfg.kind == "bert":
        params["tok_emb"] = jax.random.normal(
            keys[-1], (cfg.vocab_size, cfg.d_model)) * 0.02
        params["pos"] = jax.random.normal(
            keys[-3], (1, cfg.seq_len, cfg.d_model)) * 0.02
        # span extraction: start / end logits per token (SQuAD-style)
        params["span"] = _dense_init(keys[-4], cfg.d_model, 2)
    else:
        raise ValueError(cfg.kind)
    return params


# ---------------------------------------------------------------------------
# Forward pass
# ---------------------------------------------------------------------------

def _layer_norm(x, p, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * p["g"] + p["b"]


def _dense(x, p):
    return x @ p["w"] + p["b"]


def _maybe_qact(x, cfg: ModelConfig):
    """QAT: 5-bit fake-quant on IMC-path activations (Sec. III-B)."""
    return quant.fake_quant(x, quant.N_BITS_INPUT) if cfg.qat else x


def _attention(x, p, cfg: ModelConfig, *, fold_scale: bool,
               use_pallas: bool) -> jnp.ndarray:
    """Multi-head attention with topkima softmax.

    ``fold_scale``: whether `W_Q` already contains the 1/sqrt(d_k) factor
    (deploy-time scale-free network). During training the factor is
    applied to Q after projection — mathematically identical, so the
    trained weights can be folded without retraining (Sec. III-C).
    """
    b, sl, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head

    q = _dense(x, p["wq"])
    kk = _dense(x, p["wk"])
    v = _dense(x, p["wv"])
    if not fold_scale:
        q = q / jnp.sqrt(jnp.asarray(dh, x.dtype))

    # [b, h, sl, dh]
    q = q.reshape(b, sl, h, dh).transpose(0, 2, 1, 3)
    kk = kk.reshape(b, sl, h, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, sl, h, dh).transpose(0, 2, 1, 3)

    # The IMC paths see quantized operands under QAT: Q as PWM pulses,
    # K^T on the ternary-cell grid.
    q = _maybe_qact(q, cfg)
    if cfg.qat:
        kk = quant.quantize_ternary_cells(kk)
        v = quant.fake_quant(v, quant.N_BITS_INPUT)

    segments, ks = cfg.sub_topk()
    if use_pallas:
        # AOT path: fused pallas head, vmapped over batch*heads.
        def head(qh, kh, vh):
            return topkima_attention(qh, kh.T, vh, cfg.topk,
                                     segments=segments, ks=ks)
        out = jax.vmap(jax.vmap(head))(q, kk, v)
    else:
        logits = q @ kk.transpose(0, 1, 3, 2)
        a = tfcbp_softmax(logits, cfg.topk, segments, ks)
        out = a @ v

    out = out.transpose(0, 2, 1, 3).reshape(b, sl, d)
    return _dense(out, p["wo"])


def _encoder_layer(x, p, cfg: ModelConfig, *, fold_scale, use_pallas):
    x = x + _attention(_layer_norm(x, p["ln1"]), p, cfg,
                       fold_scale=fold_scale, use_pallas=use_pallas)
    hcat = _dense(_layer_norm(x, p["ln2"]), p["ff1"])
    x = x + _dense(jax.nn.gelu(hcat), p["ff2"])
    return x


def _patchify(images: jnp.ndarray, patch: int) -> jnp.ndarray:
    """[b, H, W, 3] → [b, n_patches, patch*patch*3]."""
    b, hgt, wid, c = images.shape
    ph, pw = hgt // patch, wid // patch
    x = images.reshape(b, ph, patch, pw, patch, c)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(b, ph * pw, patch * patch * c)


def forward(params: Params, cfg: ModelConfig, inputs: jnp.ndarray, *,
            fold_scale: bool = False, use_pallas: bool = False) -> jnp.ndarray:
    """Full model forward.

    ViT: ``inputs`` [b, H, W, 3] float images → [b, n_classes] logits.
    BERT: ``inputs`` [b, seq_len] int32 tokens → [b, seq_len, 2]
    start/end span logits.
    """
    if cfg.kind == "vit":
        x = _dense(_patchify(inputs, cfg.patch_size), params["patch"])
        cls = jnp.broadcast_to(params["cls"], (x.shape[0], 1, cfg.d_model))
        x = jnp.concatenate([cls, x], axis=1) + params["pos"]
    else:
        x = params["tok_emb"][inputs] + params["pos"]

    for p in params["layers"]:
        x = _encoder_layer(x, p, cfg, fold_scale=fold_scale,
                           use_pallas=use_pallas)
    x = _layer_norm(x, params["ln_f"])

    if cfg.kind == "vit":
        return _dense(x[:, 0], params["head"])
    return _dense(x, params["span"])


# ---------------------------------------------------------------------------
# Scale-free folding (Sec. III-C)
# ---------------------------------------------------------------------------

def fold_scale_free(params: Params, cfg: ModelConfig) -> Params:
    """Return params with 1/sqrt(d_k) folded into every W_Q.

    After folding, run :func:`forward` with ``fold_scale=True``; the
    network computes Q^s·K^T with **zero** scaling hardware. This is the
    deploy-time rewrite the paper performs on the RRAM-resident W_Q.
    """
    scale = 1.0 / jnp.sqrt(jnp.asarray(cfg.d_head, jnp.float32))
    folded = jax.tree_util.tree_map(lambda x: x, params)  # shallow-ish copy
    folded["layers"] = [
        {**layer, "wq": {"w": layer["wq"]["w"] * scale,
                         "b": layer["wq"]["b"] * scale}}
        for layer in params["layers"]
    ]
    return folded


# ---------------------------------------------------------------------------
# Losses / metrics
# ---------------------------------------------------------------------------

def vit_loss(params, cfg, images, labels):
    logits = forward(params, cfg, images)
    logp = jax.nn.log_softmax(logits, axis=-1)
    return -jnp.mean(jnp.take_along_axis(logp, labels[:, None], axis=-1))


def vit_accuracy(params, cfg, images, labels, **fw):
    logits = forward(params, cfg, images, **fw)
    return jnp.mean(jnp.argmax(logits, axis=-1) == labels)


def bert_span_loss(params, cfg, tokens, spans):
    """``spans``: [b, 2] start/end token indices."""
    logits = forward(params, cfg, tokens)          # [b, sl, 2]
    logp = jax.nn.log_softmax(logits, axis=1)
    start = jnp.take_along_axis(logp[:, :, 0], spans[:, :1], axis=1)
    end = jnp.take_along_axis(logp[:, :, 1], spans[:, 1:], axis=1)
    return -jnp.mean(start + end)


def bert_exact_match(params, cfg, tokens, spans, **fw):
    """SQuAD-style exact match of the argmax span."""
    logits = forward(params, cfg, tokens, **fw)
    pred_start = jnp.argmax(logits[:, :, 0], axis=-1)
    pred_end = jnp.argmax(logits[:, :, 1], axis=-1)
    return jnp.mean((pred_start == spans[:, 0]) & (pred_end == spans[:, 1]))


def count_params(params: Params) -> int:
    return sum(x.size for x in jax.tree_util.tree_leaves(params))
