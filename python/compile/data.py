"""Synthetic datasets standing in for the paper's benchmarks.

The paper evaluates ViT on CIFAR-10/CIFAR-100 and DistilBERT/BERT-base on
SQuAD v1.1. Neither the datasets nor pretrained checkpoints are available
in this environment, so we substitute procedurally generated tasks that
exercise the same code paths and — crucially for Fig 3 — the same
*attention statistics*: softmax rows whose mass concentrates on a few
winners, which is the property top-k selection exploits.

* **synth-CIFAR-N** (:func:`synth_cifar`): N-class 32×32×3 images. Each
  class is a fixed mixture of oriented sinusoid "textures" (class
  prototype) rendered with a random phase shift, amplitude jitter and
  pixel noise. Classification requires integrating spatial structure
  across patches — attention, not a single patch, solves it.
* **synth-SQuAD** (:func:`synth_squad`): span extraction over token
  sequences. The sequence opens with a query bigram ``[CLS] q1 q2 [SEP]``
  and the body contains exactly one occurrence of ``q1 q2`` followed by
  the answer span; single-token distractors (``q1`` alone) force real
  content-based matching. The model predicts the answer's start/end —
  SQuAD's exact-match metric applies directly.

Everything is deterministic given a seed, so train/eval splits are
reproducible across python (training) and rust (serving traces replay the
same generator via exported .npz files).
"""

from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

# Token ids reserved by synth-SQuAD.
CLS, SEP, PAD, END = 0, 1, 2, 3
FIRST_CONTENT_TOKEN = 4


# ---------------------------------------------------------------------------
# synth-CIFAR
# ---------------------------------------------------------------------------

def _class_prototypes(n_classes: int, image_size: int, seed: int) -> np.ndarray:
    """[n_classes, H, W, 3] fixed texture prototypes."""
    rng = np.random.RandomState(seed)
    yy, xx = np.mgrid[0:image_size, 0:image_size].astype(np.float32)
    protos = np.zeros((n_classes, image_size, image_size, 3), np.float32)
    for c in range(n_classes):
        img = np.zeros((image_size, image_size, 3), np.float32)
        # 3 oriented sinusoid components + a class-colored gradient
        for _ in range(3):
            theta = rng.uniform(0, np.pi)
            freq = rng.uniform(0.2, 1.2)
            phase = rng.uniform(0, 2 * np.pi)
            grating = np.sin(freq * (np.cos(theta) * xx + np.sin(theta) * yy)
                             + phase)
            color = rng.uniform(-1, 1, size=3)
            img += grating[:, :, None] * color[None, None, :]
        protos[c] = img / 3.0
    return protos


def synth_cifar(n_classes: int, n_samples: int, *, seed: int = 0,
                image_size: int = 32,
                noise: float = 0.35) -> Tuple[np.ndarray, np.ndarray]:
    """Generate (images [n, H, W, 3] float32, labels [n] int32)."""
    rng = np.random.RandomState(seed + 1)
    protos = _class_prototypes(n_classes, image_size, seed)
    labels = rng.randint(0, n_classes, size=n_samples).astype(np.int32)
    images = np.empty((n_samples, image_size, image_size, 3), np.float32)
    for i, c in enumerate(labels):
        img = protos[c]
        # random translation (texture phase shift)
        img = np.roll(img, shift=(rng.randint(image_size),
                                  rng.randint(image_size)), axis=(0, 1))
        amp = rng.uniform(0.7, 1.3)
        images[i] = amp * img + noise * rng.randn(*img.shape)
    return images, labels


# ---------------------------------------------------------------------------
# synth-SQuAD
# ---------------------------------------------------------------------------

def synth_squad(n_samples: int, *, seed: int = 0, seq_len: int = 128,
                vocab_size: int = 64, max_answer_len: int = 4,
                n_distractors: int = 4) -> Tuple[np.ndarray, np.ndarray]:
    """Generate (tokens [n, seq_len] int32, spans [n, 2] int32).

    Layout: ``[CLS] q1 q2 [SEP] body...``. The body is random content
    tokens with exactly one ``q1 q2`` bigram; the answer span is the
    1..max_answer tokens that follow it, terminated by the [END] sentinel
    (so the end position is *predictable* from content, as in SQuAD where
    answers end at natural boundaries). ``spans`` holds (start, end)
    inclusive indices. q1-only distractors are scattered in the body.
    """
    rng = np.random.RandomState(seed + 2)
    toks = np.empty((n_samples, seq_len), np.int32)
    spans = np.empty((n_samples, 2), np.int32)
    body_start = 4
    for i in range(n_samples):
        q1, q2 = rng.choice(
            np.arange(FIRST_CONTENT_TOKEN, vocab_size), size=2, replace=False)
        body = rng.randint(FIRST_CONTENT_TOKEN, vocab_size,
                           size=seq_len - body_start).astype(np.int32)
        # remove accidental q1 q2 bigrams from the random body
        for j in range(len(body) - 1):
            while body[j] == q1 and body[j + 1] == q2:
                body[j + 1] = rng.randint(FIRST_CONTENT_TOKEN, vocab_size)
        ans_len = rng.randint(1, max_answer_len + 1)
        # place the match so bigram + answer + END fit
        pos = rng.randint(0, len(body) - (3 + ans_len))
        body[pos], body[pos + 1] = q1, q2
        body[pos + 2 + ans_len] = END  # sentinel terminates the span
        # distractors: lone q1 followed by something != q2
        for _ in range(n_distractors):
            dpos = rng.randint(0, len(body) - 2)
            if abs(dpos - pos) <= 3 + ans_len:
                continue
            body[dpos] = q1
            if body[dpos + 1] == q2:
                body[dpos + 1] = (q2 + 1 - FIRST_CONTENT_TOKEN) % (
                    vocab_size - FIRST_CONTENT_TOKEN) + FIRST_CONTENT_TOKEN
        toks[i, 0], toks[i, 1], toks[i, 2], toks[i, 3] = CLS, q1, q2, SEP
        toks[i, body_start:] = body
        start = body_start + pos + 2
        spans[i] = (start, start + ans_len - 1)
    return toks, spans


# ---------------------------------------------------------------------------
# Batching helpers
# ---------------------------------------------------------------------------

def batches(arrays, batch_size: int, *, seed: int = 0, epochs: int = 1000):
    """Endless shuffled mini-batch generator over aligned arrays."""
    n = arrays[0].shape[0]
    rng = np.random.RandomState(seed + 3)
    for _ in range(epochs):
        order = rng.permutation(n)
        for i in range(0, n - batch_size + 1, batch_size):
            idx = order[i:i + batch_size]
            yield tuple(jnp.asarray(a[idx]) for a in arrays)
