"""AOT pipeline: train (or load) checkpoints, lower to HLO text, emit
artifacts + manifest for the rust runtime.

Interchange format is **HLO text**, not serialized ``HloModuleProto``:
jax ≥ 0.5 emits protos with 64-bit instruction ids which the ``xla``
crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text
parser reassigns ids and round-trips cleanly (see /opt/xla-example).

Emitted artifacts (``make artifacts``):

* ``{model}_k{K}_b{B}.hlo.txt`` — full inference graph for model family
  ``model`` with topkima k=K at batch B, **weights baked in as
  constants** (the fabric's weights are programmed once; the request path
  carries only activations). Scale-free folding (Sec. III-C) is applied
  before lowering, so the exported graph contains no 1/sqrt(d_k) scaling.
* ``attention_head_k{K}.hlo.txt`` — the fused L1 Pallas topkima attention
  head on its own (interpret=True → plain HLO), proving the
  pallas→HLO→PJRT path and used by the rust macro parity tests.
* ``eval_{task}.{bin,json}`` — the synthetic eval split in a flat
  little-endian binary + JSON shape header, replayed by the rust serving
  examples.
* ``manifest.json`` — index of all of the above with shapes, dtypes,
  configs and checkpoint eval accuracy.

Python never runs again after this step: the rust binary is self-contained.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import data as D
from . import model as M
from . import train as T
from .kernels.attention import topkima_attention

# Batch sizes the serving batcher may form; one executable each (bucketed
# batching, the standard PJRT serving pattern).
SERVE_BATCH_SIZES = (1, 2, 4, 8, 16)
# k values exported for the rust-side Fig 3 re-check.
SWEEP_KS = (1, 2, 5, 10, 0)  # 0 == full softmax baseline
# batch used by the rust accuracy-sweep example
EVAL_BATCH = 32

# Trained-model hyperparameters (small enough to train at build time, big
# enough to show the paper's top-k behaviour).
VIT_CFG = dataclasses.replace(
    M.VIT_TINY, d_model=64, n_heads=4, n_layers=3, d_ff=128, n_classes=10)
BERT_CFG = dataclasses.replace(
    M.BERT_TINY, d_model=128, n_heads=4, n_layers=3, d_ff=256, seq_len=64)

TRAIN_STEPS = {"vit": 600, "bert": 3000}
TRAIN_LR = {"vit": 1e-3, "bert": 1e-3}


def to_hlo_text(lowered) -> str:
    """jax Lowered → XLA HLO text (the rust-loadable format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    # print_large_constants=True: the default printer ELIDES big weight
    # literals as `constant({...})`, which the rust-side parser fills
    # with zeros — the exported graph must carry every weight verbatim.
    return comp.as_hlo_text(True)


def _checkpoint_path(out_dir: Path, name: str) -> Path:
    return out_dir / "checkpoints" / f"{name}.pkl"


def train_or_load(out_dir: Path, name: str, cfg: M.ModelConfig,
                  force: bool = False):
    """Train the build-time checkpoint for one model family (cached)."""
    ckpt = _checkpoint_path(out_dir, name)
    if ckpt.exists() and not force:
        params, cfg2, meta = T.load_checkpoint(ckpt)
        print(f"[aot] loaded cached {name}: acc={meta.get('accuracy'):.3f}")
        return params, cfg2, meta
    print(f"[aot] training {name} ({cfg.kind}, topk={cfg.topk}) ...")
    t0 = time.time()
    out = T.train_model(cfg, steps=TRAIN_STEPS[cfg.kind],
                        lr=TRAIN_LR[cfg.kind],
                        n_train=TRAIN_N[cfg.kind], log_every=200)
    meta = {"accuracy": out["accuracy"], "train_secs": time.time() - t0,
            "steps": TRAIN_STEPS[cfg.kind]}
    print(f"[aot] {name}: eval acc {out['accuracy']:.3f} "
          f"({meta['train_secs']:.0f}s)")
    T.save_checkpoint(ckpt, out["params"], cfg, meta)
    return out["params"], cfg, meta


def export_model(out_dir: Path, name: str, params, cfg: M.ModelConfig,
                 batch: int, k: int) -> dict:
    """Lower one (model, k, batch) inference graph to HLO text."""
    kcfg = dataclasses.replace(cfg, topk=k)
    folded = M.fold_scale_free(params, kcfg)

    def infer(x):
        return (M.forward(folded, kcfg, x, fold_scale=True),)

    if cfg.kind == "vit":
        spec = jax.ShapeDtypeStruct(
            (batch, cfg.image_size, cfg.image_size, 3), jnp.float32)
        in_meta = {"shape": list(spec.shape), "dtype": "f32"}
        out_shape = [batch, cfg.n_classes]
    else:
        spec = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
        in_meta = {"shape": list(spec.shape), "dtype": "i32"}
        out_shape = [batch, cfg.seq_len, 2]

    lowered = jax.jit(infer).lower(spec)
    text = to_hlo_text(lowered)
    fname = f"{name}_k{k}_b{batch}.hlo.txt"
    (out_dir / fname).write_text(text)
    print(f"[aot] wrote {fname} ({len(text) / 1e6:.1f} MB)")
    return {"file": fname, "model": name, "k": k, "batch": batch,
            "input": in_meta, "output_shape": out_shape,
            "kind": cfg.kind, "cfg": dataclasses.asdict(kcfg)}


def export_attention_head(out_dir: Path, k: int, sl: int = 64,
                          d_head: int = 32) -> dict:
    """Lower the fused Pallas topkima head (interpret=True) to HLO."""
    def head(q, kt, v):
        return (topkima_attention(q, kt, v, k),)

    specs = [jax.ShapeDtypeStruct(s, jnp.float32)
             for s in ((sl, d_head), (d_head, sl), (sl, d_head))]
    lowered = jax.jit(head).lower(*specs)
    text = to_hlo_text(lowered)
    fname = f"attention_head_k{k}.hlo.txt"
    (out_dir / fname).write_text(text)
    print(f"[aot] wrote {fname}")
    return {"file": fname, "model": "attention_head", "k": k,
            "sl": sl, "d_head": d_head,
            "input": {"shapes": [[sl, d_head], [d_head, sl], [sl, d_head]],
                      "dtype": "f32"}}


def export_eval_set(out_dir: Path, name: str, cfg: M.ModelConfig,
                    n_eval: int, seed: int = 0) -> dict:
    """Write the eval split as raw little-endian + JSON header for rust."""
    _, (xs, ys) = T.make_dataset(cfg, n_train=TRAIN_N[cfg.kind],
                                 n_eval=n_eval, seed=seed)
    xbin = out_dir / f"eval_{name}_x.bin"
    ybin = out_dir / f"eval_{name}_y.bin"
    np.asarray(xs).astype("<f4" if cfg.kind == "vit" else "<i4").tofile(xbin)
    np.asarray(ys).astype("<i4").tofile(ybin)
    meta = {
        "x_file": xbin.name, "y_file": ybin.name,
        "x_shape": list(np.asarray(xs).shape),
        "y_shape": list(np.asarray(ys).shape),
        "x_dtype": "f32" if cfg.kind == "vit" else "i32",
        "y_dtype": "i32", "kind": cfg.kind,
    }
    (out_dir / f"eval_{name}.json").write_text(json.dumps(meta, indent=1))
    print(f"[aot] wrote eval_{name} ({meta['x_shape']})")
    return meta


# must match train_model defaults so the eval split equals the one used to
# report checkpoint accuracy (train is a prefix, eval the suffix).
TRAIN_N = {"vit": 4096, "bert": 16384}


def export_parity_vectors(out_dir: Path, seed: int = 0) -> None:
    """Golden vectors for the rust `quant` mirror (rust/tests/parity.rs).

    Random floats + the python-side quantization codes; the rust side must
    reproduce every code exactly (bit-for-bit contract of DESIGN.md §3).
    """
    import numpy as np

    from . import quant

    rng = np.random.RandomState(seed)
    xs = (rng.randn(64) * 2.0).astype(np.float32)
    q_scale = float(quant.symmetric_scale(jnp.asarray(xs), quant.N_BITS_INPUT))
    pwm = quant.quantize_codes(jnp.asarray(xs), quant.N_BITS_INPUT, q_scale)

    ws = (rng.randn(64) * 1.5).astype(np.float32)
    w_scale = float(quant.symmetric_scale(jnp.asarray(ws), 4))
    wcodes = jnp.clip(jnp.round(jnp.asarray(ws) / w_scale), -7, 7).astype(
        jnp.int32)

    vs = (rng.randn(64) * 3.0).astype(np.float32)
    fs = 4.0
    adc = quant.adc_codes(jnp.asarray(vs), fs, n_bits=quant.N_BITS_ADC)

    blob = {
        "pwm": {"x": [float(v) for v in xs], "scale": q_scale,
                "codes": [int(c) for c in np.asarray(pwm)]},
        "weight": {"w": [float(v) for v in ws], "scale": w_scale,
                   "codes": [int(c) for c in np.asarray(wcodes)]},
        "adc": {"v": [float(v) for v in vs], "full_scale": fs,
                "codes": [int(c) for c in np.asarray(adc)]},
    }
    (out_dir / "parity_vectors.json").write_text(json.dumps(blob))
    print("[aot] wrote parity_vectors.json")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--retrain", action="store_true",
                    help="ignore cached checkpoints")
    ap.add_argument("--quick", action="store_true",
                    help="minimal artifact set (smoke tests)")
    args = ap.parse_args()
    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)
    if args.quick:  # smoke-test the pipeline, not the accuracy
        TRAIN_STEPS.update({"vit": 60, "bert": 120})
        TRAIN_N.update({"vit": 1024, "bert": 2048})

    manifest = {"models": [], "attention_heads": [], "eval_sets": {},
                "checkpoints": {}}

    families = [("bert", BERT_CFG)] if args.quick else [
        ("vit", VIT_CFG), ("bert", BERT_CFG)]

    for name, cfg in families:
        params, cfg, meta = train_or_load(out_dir, name, cfg, args.retrain)
        manifest["checkpoints"][name] = {
            "accuracy": meta.get("accuracy"),
            "params": M.count_params(params),
            "cfg": dataclasses.asdict(cfg),
        }
        manifest["eval_sets"][name] = export_eval_set(
            out_dir, name, cfg, n_eval=1024)

        ks = (cfg.topk,) if args.quick else SWEEP_KS
        for k in ks:
            manifest["models"].append(
                export_model(out_dir, name, params, cfg, EVAL_BATCH, k))
        # serving executables at the batcher's bucket sizes (default k)
        batches = (1, 4) if args.quick else SERVE_BATCH_SIZES
        for b in batches:
            manifest["models"].append(
                export_model(out_dir, name, params, cfg, b, cfg.topk))

    for k in ((5,) if args.quick else (1, 5, 10)):
        manifest["attention_heads"].append(export_attention_head(out_dir, k))

    export_parity_vectors(out_dir)

    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"[aot] manifest with {len(manifest['models'])} model "
          f"executables -> {out_dir / 'manifest.json'}")


if __name__ == "__main__":
    main()
