"""Quantization math shared by the whole stack.

This module is the single numerical contract between

* the L1 Pallas kernels (``kernels/``) that model what the topkima
  hardware computes,
* the L2 model (``model.py``) trained with quantization-aware training
  (QAT), and
* the L3 rust circuit simulator (``rust/src/quant/``), which mirrors the
  same functions so the trained network and the simulated fabric agree
  bit-for-bit on quantized values.

Hardware mapping (Sec. III-A of the paper):

* **Activations / Q inputs** — 5-bit signed, applied to the SRAM word
  lines as pulse-width-modulated (PWM) pulses: ``quantize_pwm``.
* **K^T weights** — 15 levels (-7..7, "approximately 4 bits"), stored as
  three ternary dual-10T cells driven with input pulses scaled 1/2/4:
  ``quantize_ternary_cells`` / ``pack_ternary_cells``.
* **ADC** — n-bit ramp in-memory ADC digitizing the bitline MAC voltage:
  ``adc_quantize``. The decreasing-ramp top-k behaviour itself lives in
  ``kernels/topk_softmax.py``; here we only model the transfer function.

All fake-quant functions use straight-through estimators (STE) so they can
sit inside a training graph (QAT, Sec. III-B).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Hardware constants (Sec. III-A / IV-B of the paper)
# ---------------------------------------------------------------------------

#: bit-width of Q activations applied as PWM word-line pulses
N_BITS_INPUT = 5
#: bit-width of the ramp in-memory ADC
N_BITS_ADC = 5
#: number of ternary cells ganged per K^T weight (input scales 1, 2, 4)
CELLS_PER_WEIGHT = 3
#: resulting weight range: -7 .. +7 (15 levels, "approximately 4 bits")
WEIGHT_LEVELS = 2 ** CELLS_PER_WEIGHT - 1  # 7
#: per-cell input pulse scale factors
CELL_SCALES = (1, 2, 4)


def _ste_round(x: jnp.ndarray) -> jnp.ndarray:
    """Round with a straight-through gradient (identity in backward)."""
    return x + jax.lax.stop_gradient(jnp.round(x) - x)


# ---------------------------------------------------------------------------
# Symmetric uniform fake-quant (QAT building block)
# ---------------------------------------------------------------------------

def symmetric_scale(x: jnp.ndarray, n_bits: int, axis=None) -> jnp.ndarray:
    """Scale mapping ``max|x|`` to the top code of a signed n-bit grid."""
    qmax = 2 ** (n_bits - 1) - 1
    amax = jnp.max(jnp.abs(x), axis=axis, keepdims=axis is not None)
    return jnp.maximum(amax, 1e-8) / qmax


def fake_quant(x: jnp.ndarray, n_bits: int, scale=None, axis=None) -> jnp.ndarray:
    """Symmetric uniform fake-quantization with an STE gradient.

    ``q = clip(round(x / s), -qmax, qmax) * s`` — the value grid the
    hardware sees, kept in float for training.
    """
    qmax = 2 ** (n_bits - 1) - 1
    s = symmetric_scale(x, n_bits, axis=axis) if scale is None else scale
    q = _ste_round(x / s)
    q = jnp.clip(q, -qmax, qmax)
    return q * s


def quantize_codes(x: jnp.ndarray, n_bits: int, scale) -> jnp.ndarray:
    """Integer codes (no STE) — what actually travels on the hardware."""
    qmax = 2 ** (n_bits - 1) - 1
    return jnp.clip(jnp.round(x / scale), -qmax, qmax).astype(jnp.int32)


# ---------------------------------------------------------------------------
# PWM input quantization (Q activations)
# ---------------------------------------------------------------------------

def quantize_pwm(x: jnp.ndarray, scale=None) -> jnp.ndarray:
    """5-bit signed PWM fake-quant of word-line inputs (Q values)."""
    return fake_quant(x, N_BITS_INPUT, scale=scale)


# ---------------------------------------------------------------------------
# Ternary-cell weight quantization (K^T)
# ---------------------------------------------------------------------------

def quantize_ternary_cells(w: jnp.ndarray, scale=None) -> jnp.ndarray:
    """Fake-quant K^T onto the 15-level (-7..7) ternary-cell grid."""
    if scale is None:
        scale = symmetric_scale(w, CELLS_PER_WEIGHT + 1)  # qmax == 7
    q = _ste_round(w / scale)
    q = jnp.clip(q, -WEIGHT_LEVELS, WEIGHT_LEVELS)
    return q * scale


def pack_ternary_cells(codes: jnp.ndarray) -> jnp.ndarray:
    """Decompose integer weight codes (-7..7) into 3 ternary cells.

    Cell ``i`` holds a value in {-1, 0, +1} and is driven with an input
    pulse scaled by ``CELL_SCALES[i]``; ``sum_i cell_i * scale_i`` must
    reconstruct the code. Mirrors the bit-plane split the hardware uses
    (sign-magnitude binary over the ganged cells).

    Returns an array with a trailing axis of size ``CELLS_PER_WEIGHT``.
    """
    sign = jnp.sign(codes)
    mag = jnp.abs(codes)
    cells = [((mag >> i) & 1) * sign for i in range(CELLS_PER_WEIGHT)]
    return jnp.stack(cells, axis=-1).astype(jnp.int32)


def unpack_ternary_cells(cells: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack_ternary_cells`."""
    scales = jnp.asarray(CELL_SCALES, dtype=cells.dtype)
    return jnp.sum(cells * scales, axis=-1)


# ---------------------------------------------------------------------------
# Ramp-ADC transfer function
# ---------------------------------------------------------------------------

def adc_quantize(v: jnp.ndarray, full_scale, n_bits: int = N_BITS_ADC) -> jnp.ndarray:
    """n-bit ramp-ADC transfer function over a symmetric full-scale range.

    The ramp IMA compares the MAC bitline voltage against ``2**n`` equally
    spaced ramp steps; the output code is the step index at the crossing.
    Modeled as a mid-tread uniform quantizer over ``[-full_scale,
    +full_scale]`` with an STE gradient so it can participate in QAT.
    """
    qmax = 2 ** (n_bits - 1) - 1
    lsb = full_scale / qmax
    q = _ste_round(v / lsb)
    q = jnp.clip(q, -(qmax + 1), qmax)
    return q * lsb


def adc_codes(v: jnp.ndarray, full_scale, n_bits: int = N_BITS_ADC) -> jnp.ndarray:
    """Integer ADC output codes (what the arbiter-encoder latches)."""
    qmax = 2 ** (n_bits - 1) - 1
    lsb = full_scale / qmax
    return jnp.clip(jnp.round(v / lsb), -(qmax + 1), qmax).astype(jnp.int32)
