"""Pallas kernel for the topkima top-k softmax (the paper's L1 hot-spot).

The hardware (Fig 2) never sorts: a *decreasing* ramp ADC lets larger MAC
voltages cross earlier, an arbiter-encoder latches the first k crossings
(ties resolved toward smaller column addresses) and a counter stops the
conversion early. The numerical contract that reaches the digital softmax
core is therefore exactly "softmax over the k largest logits, hard zero
elsewhere" — which is what this kernel computes, tiled so that one grid
row == one softmax row and one block == one crossbar's worth of columns.

``interpret=True`` everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; on a real TPU the same BlockSpecs map a crossbar tile to a
VMEM tile (see DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools
from typing import Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Rows of the logits matrix processed per grid step. One softmax row is one
# set of simultaneous ramp conversions in the macro; blocking several rows
# amortizes pallas grid overhead in interpret mode.
DEFAULT_ROW_BLOCK = 8


def _topk_mask_rows(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """[rows, d] boolean mask of each row's k largest entries.

    k unrolled argmax-and-mask steps: each step latches one ramp crossing,
    exactly like the decreasing-ramp arbiter (ties → first occurrence →
    smaller column address). Avoids the ``topk`` HLO op, which the rust
    runtime's xla_extension 0.5.1 parser cannot load (see ref.py).
    """
    d = x.shape[-1]
    if k >= d:
        return jnp.ones(x.shape, dtype=bool)
    neg = jnp.finfo(x.dtype).min
    remaining = x
    mask = jnp.zeros(x.shape, dtype=bool)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)
        hit = jax.nn.one_hot(idx, d, dtype=jnp.float32) > 0.5
        mask = mask | hit
        remaining = jnp.where(hit, neg, remaining)
    return mask


def _topk_softmax_kernel(x_ref, o_ref, *, k: int):
    """One grid step: top-k softmax over a [row_block, d] tile."""
    x = x_ref[...]
    mask = _topk_mask_rows(x, k)
    neg = jnp.finfo(x.dtype).min
    masked = jnp.where(mask, x, neg)
    # Numerically stable softmax over the selected k values only.
    m = jnp.max(masked, axis=-1, keepdims=True)
    e = jnp.where(mask, jnp.exp(masked - m), jnp.zeros_like(x))
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("k", "row_block"))
def topk_softmax(x: jnp.ndarray, k: int,
                 row_block: int = DEFAULT_ROW_BLOCK) -> jnp.ndarray:
    """Top-k softmax along the last axis via a Pallas kernel.

    ``x`` may have any leading batch shape; the last axis is the softmax
    axis (one ramp conversion per element). Rows are tiled ``row_block`` at
    a time; the full row stays resident (the arbiter sees every column of
    the crossbar group simultaneously).
    """
    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)

    rb = min(row_block, rows)
    pad = (-rows) % rb
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // rb,)

    out = pl.pallas_call(
        functools.partial(_topk_softmax_kernel, k=k),
        grid=grid,
        in_specs=[pl.BlockSpec((rb, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        interpret=True,
    )(x2)

    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)


def _sub_topk_softmax_kernel(x_ref, o_ref, *, segments: tuple, ks: tuple):
    """One grid step of sub-top-k softmax over a [row_block, d] tile.

    Each segment is one physical crossbar: selection is local (no global
    information), the union of selections feeds one shared softmax — the
    digital core receives the concatenated k_i values (Sec. III-A).
    """
    x = x_ref[...]
    masks, start = [], 0
    for seg, ki in zip(segments, ks):
        masks.append(_topk_mask_rows(x[:, start:start + seg], ki))
        start += seg
    mask = jnp.concatenate(masks, axis=-1)
    neg = jnp.finfo(x.dtype).min
    masked = jnp.where(mask, x, neg)
    m = jnp.max(masked, axis=-1, keepdims=True)
    e = jnp.where(mask, jnp.exp(masked - m), jnp.zeros_like(x))
    o_ref[...] = e / jnp.sum(e, axis=-1, keepdims=True)


@functools.partial(jax.jit, static_argnames=("segments", "ks", "row_block"))
def sub_topk_softmax(x: jnp.ndarray, segments: Sequence[int],
                     ks: Sequence[int],
                     row_block: int = DEFAULT_ROW_BLOCK) -> jnp.ndarray:
    """Sub-top-k softmax: per-crossbar local top-k_i, union, softmax.

    Models the crossbar-size limitation of Sec. III-A / Fig 4(c): when
    ``K^T`` is split across crossbars, each array i picks its own top-k_i
    with ``sum(k_i) == k`` and no global sort ever happens.
    """
    segments, ks = tuple(segments), tuple(ks)
    assert len(segments) == len(ks)
    assert sum(segments) == x.shape[-1]

    orig_shape = x.shape
    d = orig_shape[-1]
    rows = 1
    for s in orig_shape[:-1]:
        rows *= s
    x2 = x.reshape(rows, d)

    rb = min(row_block, rows)
    pad = (-rows) % rb
    if pad:
        x2 = jnp.pad(x2, ((0, pad), (0, 0)))
    grid = (x2.shape[0] // rb,)

    out = pl.pallas_call(
        functools.partial(_sub_topk_softmax_kernel, segments=segments, ks=ks),
        grid=grid,
        in_specs=[pl.BlockSpec((rb, d), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((rb, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x2.shape, x2.dtype),
        interpret=True,
    )(x2)

    if pad:
        out = out[:rows]
    return out.reshape(orig_shape)


def crossbar_split(d: int, k: int, crossbar_cols: int) -> tuple:
    """Split d softmax columns over crossbars and apportion k among them.

    Matches the paper's examples: d=384, 256-wide crossbars, k=5 →
    segments (256, 128) with sub-k (3, 2); d=384, 128-wide, k=5 →
    (128, 128, 128) with (2, 2, 1). k is spread proportionally to segment
    width, remainder to earlier (larger/lower-address) segments, each
    segment getting at least 1 when k >= n_segments.
    """
    n_seg = -(-d // crossbar_cols)
    segments = tuple(min(crossbar_cols, d - i * crossbar_cols)
                     for i in range(n_seg))
    if n_seg == 1:
        return segments, (k,)
    # Largest-remainder apportionment of k over segment widths. Matches the
    # paper: (256,128)+k=5 -> (3,2); (128,128,128)+k=5 -> (2,2,1).
    base = [k * s // d for s in segments]
    fracs = [(k * s) % d for s in segments]
    order = sorted(range(n_seg), key=lambda i: (-fracs[i], i))
    for i in range(k - sum(base)):
        base[order[i % n_seg]] += 1
    # Every crossbar contributes at least one winner when k allows it.
    if k >= n_seg:
        for j in range(n_seg):
            while base[j] == 0:
                donor = max(range(n_seg), key=lambda t: base[t])
                base[donor] -= 1
                base[j] += 1
    return segments, tuple(base)
