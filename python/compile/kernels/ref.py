"""Pure-jnp oracles for every Pallas kernel.

These are the correctness ground truth: each kernel in this package has a
reference implementation here written with plain ``jax.numpy`` (no Pallas),
and ``python/tests`` asserts ``allclose`` between kernel and oracle across
hypothesis-generated shapes, dtypes and k values.

Semantics follow the topkima hardware (Sec. III-A):

* top-k selection uses the decreasing-ramp crossing order — descending by
  value, ties broken toward the smaller column address, which is exactly
  ``jax.lax.top_k``'s tie rule;
* sub-top-k splits the columns into crossbar-sized segments, selects
  ``k_i`` per segment with ``sum(k_i) == k``, and unions the selections;
* non-selected logits contribute nothing to softmax (their probability
  is exactly zero — the digital softmax core only ever sees k values).
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from .. import quant


# ---------------------------------------------------------------------------
# Top-k softmax (the topkima numerical contract)
# ---------------------------------------------------------------------------

def topk_mask_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Boolean mask of the k largest entries along the last axis.

    Ties are broken toward smaller indices (the arbiter's preference for
    smaller column addresses) — ``argmax`` returns the first occurrence,
    matching that rule exactly.

    Implemented as k unrolled argmax-and-mask steps rather than
    ``jax.lax.top_k`` for two reasons: (1) it mirrors the hardware, where
    the decreasing ramp latches crossings one by one; (2) the ``topk`` HLO
    op emitted by ``lax.top_k`` post-dates the HLO parser in xla_extension
    0.5.1 that the rust runtime links against, so AOT-exported graphs must
    avoid it (argmax lowers to plain reduce/iota/select ops). k is small
    (≤ 20 in the paper), so the unroll is cheap.
    """
    d = x.shape[-1]
    if k >= d:
        return jnp.ones(x.shape, dtype=bool)
    neg = jnp.finfo(x.dtype).min
    remaining = x
    mask = jnp.zeros(x.shape, dtype=bool)
    for _ in range(k):
        idx = jnp.argmax(remaining, axis=-1)
        hit = jax.nn.one_hot(idx, d, dtype=jnp.float32) > 0.5
        mask = mask | hit
        remaining = jnp.where(hit, neg, remaining)
    return mask


def topk_mask_lax(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Same mask via ``jax.lax.top_k`` — test-only cross-check oracle.

    Not used on any export path (see :func:`topk_mask_ref`); tests assert
    it agrees with the iterative mask on random and tied inputs.
    """
    d = x.shape[-1]
    if k >= d:
        return jnp.ones(x.shape, dtype=bool)
    _, idx = jax.lax.top_k(x, k)
    onehot = jax.nn.one_hot(idx, d, dtype=jnp.float32)
    return jnp.sum(onehot, axis=-2) > 0


def topk_softmax_ref(x: jnp.ndarray, k: int) -> jnp.ndarray:
    """Softmax over only the k largest logits per row; zeros elsewhere."""
    mask = topk_mask_ref(x, k)
    neg = jnp.finfo(x.dtype).min
    masked = jnp.where(mask, x, neg)
    y = jax.nn.softmax(masked, axis=-1)
    # Hard zero outside the selection: the digital softmax core never sees
    # the other d-k values at all.
    return jnp.where(mask, y, jnp.zeros_like(y))


def sub_topk_mask_ref(x: jnp.ndarray, segments: Sequence[int],
                      ks: Sequence[int]) -> jnp.ndarray:
    """Mask for sub-top-k over crossbar segments (Sec. III-A, Fig 4c).

    ``segments`` are the column counts of each crossbar split of the row;
    segment ``i`` independently selects its ``ks[i]`` largest entries
    (no global information is exchanged between crossbars).
    """
    assert sum(segments) == x.shape[-1], (segments, x.shape)
    assert len(segments) == len(ks)
    parts, start = [], 0
    for seg, ki in zip(segments, ks):
        parts.append(topk_mask_ref(x[..., start:start + seg], ki))
        start += seg
    return jnp.concatenate(parts, axis=-1)


def sub_topk_softmax_ref(x: jnp.ndarray, segments: Sequence[int],
                         ks: Sequence[int]) -> jnp.ndarray:
    """Softmax over the union of per-crossbar sub-top-k selections."""
    mask = sub_topk_mask_ref(x, segments, ks)
    neg = jnp.finfo(x.dtype).min
    y = jax.nn.softmax(jnp.where(mask, x, neg), axis=-1)
    return jnp.where(mask, y, jnp.zeros_like(y))


# ---------------------------------------------------------------------------
# IMC-quantized Q·K^T (what the SRAM macro computes)
# ---------------------------------------------------------------------------

def imc_qkt_ref(q: jnp.ndarray, kt: jnp.ndarray, *,
                q_scale=None, w_scale=None, adc_full_scale=None,
                n_bits_adc: int = quant.N_BITS_ADC) -> jnp.ndarray:
    """Reference for the IMC Q·K^T macro: PWM-quantized inputs × 15-level
    ternary-cell weights, bitline accumulation, then the ramp-ADC transfer
    function per output.

    ``q``: [..., m, d] activations (rows applied one at a time as PWM).
    ``kt``: [d, n] weights stored in the crossbar.
    Returns the ADC-quantized MAC values, same dtype as ``q``.
    """
    qq = quant.quantize_pwm(q, scale=q_scale)
    wq = quant.quantize_ternary_cells(kt, scale=w_scale)
    mac = qq @ wq
    if adc_full_scale is None:
        adc_full_scale = jnp.maximum(jnp.max(jnp.abs(mac)), 1e-8)
    return quant.adc_quantize(mac, adc_full_scale, n_bits=n_bits_adc)


# ---------------------------------------------------------------------------
# Fused scale-free topkima attention
# ---------------------------------------------------------------------------

def attention_ref(q: jnp.ndarray, kt: jnp.ndarray, v: jnp.ndarray, k: int,
                  *, scale_free: bool = True) -> jnp.ndarray:
    """One attention head with topkima softmax.

    ``scale_free=True`` assumes the 1/sqrt(d_k) factor was already folded
    into W_Q (Sec. III-C), so no scaling happens here. Otherwise the
    conventional scaling is applied (used as the baseline in tests).
    """
    d_k = q.shape[-1]
    logits = q @ kt
    if not scale_free:
        logits = logits / jnp.sqrt(jnp.asarray(d_k, dtype=q.dtype))
    a = topk_softmax_ref(logits, k)
    return a @ v


def softmax_ref(x: jnp.ndarray) -> jnp.ndarray:
    """Plain full softmax (the conventional-macro baseline)."""
    return jax.nn.softmax(x, axis=-1)
