"""Fused scale-free topkima attention Pallas kernel.

One grid step computes one (row-tile × full-d) slice of a single head:

    logits = Q^s · K^T          (scale-free: 1/sqrt(d_k) folded into W_Q)
    A      = topk_softmax(logits)   (the topkima macro's contract)
    out    = A · V

Fusing all three keeps the logits tile in VMEM — the paper's macro never
materializes Q·K^T in a buffer either: the MAC voltages go straight into
the ramp IMA and only k scores per row ever leave the array. The optional
``quantized=True`` path inserts the IMC transfer functions (PWM × ternary
cells × ADC) so the kernel computes bit-exactly what the fabric computes.
"""

from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import quant
from .topk_softmax import _topk_mask_rows

DEFAULT_ROW_BLOCK = 32


def _attention_kernel(q_ref, kt_ref, v_ref, o_ref, *, k: int,
                      segments: Optional[tuple], ks: Optional[tuple],
                      quantized: bool, q_scale: float, w_scale: float,
                      adc_full_scale: float, n_bits_adc: int):
    """One grid step: fused QK^T → topk-softmax → AV for a row tile."""
    q = q_ref[...]
    kt = kt_ref[...]
    v = v_ref[...]

    if quantized:
        qq = quant.quantize_pwm(q, scale=q_scale)
        wq = quant.quantize_ternary_cells(kt, scale=w_scale)
        logits = quant.adc_quantize(qq @ wq, adc_full_scale,
                                    n_bits=n_bits_adc)
    else:
        logits = q @ kt

    if segments is None:
        mask = _topk_mask_rows(logits, k)
    else:
        masks, start = [], 0
        for seg, ki in zip(segments, ks):
            masks.append(_topk_mask_rows(logits[:, start:start + seg], ki))
            start += seg
        mask = jnp.concatenate(masks, axis=-1)

    neg = jnp.finfo(logits.dtype).min
    masked = jnp.where(mask, logits, neg)
    m = jnp.max(masked, axis=-1, keepdims=True)
    e = jnp.where(mask, jnp.exp(masked - m), jnp.zeros_like(logits))
    a = e / jnp.sum(e, axis=-1, keepdims=True)
    o_ref[...] = a @ v


@functools.partial(jax.jit, static_argnames=(
    "k", "segments", "ks", "quantized", "q_scale", "w_scale",
    "adc_full_scale", "n_bits_adc", "row_block"))
def topkima_attention(q: jnp.ndarray, kt: jnp.ndarray, v: jnp.ndarray,
                      k: int, *,
                      segments: Optional[Sequence[int]] = None,
                      ks: Optional[Sequence[int]] = None,
                      quantized: bool = False,
                      q_scale: float = 1.0, w_scale: float = 1.0,
                      adc_full_scale: float = 1.0,
                      n_bits_adc: int = quant.N_BITS_ADC,
                      row_block: int = DEFAULT_ROW_BLOCK) -> jnp.ndarray:
    """One attention head with the topkima softmax, fused in Pallas.

    ``q``: [sl_q, d_k] scale-free queries (Q^s = X·W_Q/sqrt(d_k));
    ``kt``: [d_k, sl] keys as stored in the crossbar; ``v``: [sl, d_v].
    ``segments``/``ks`` enable per-crossbar sub-top-k (Fig 4c). With
    ``quantized=True`` the IMC transfer functions are applied and the
    result matches the rust circuit simulator bit-for-bit.
    """
    if segments is not None:
        segments = tuple(segments)
        ks = tuple(ks)
        assert sum(ks) == k, (ks, k)

    sl_q, d_k = q.shape
    d_k2, sl = kt.shape
    sl2, d_v = v.shape
    assert d_k == d_k2 and sl == sl2, (q.shape, kt.shape, v.shape)

    rb = min(row_block, sl_q)
    pad = (-sl_q) % rb
    qp = jnp.pad(q, ((0, pad), (0, 0))) if pad else q
    grid = (qp.shape[0] // rb,)

    out = pl.pallas_call(
        functools.partial(
            _attention_kernel, k=k, segments=segments, ks=ks,
            quantized=quantized, q_scale=q_scale, w_scale=w_scale,
            adc_full_scale=adc_full_scale, n_bits_adc=n_bits_adc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, d_k), lambda i: (i, 0)),
            pl.BlockSpec((d_k, sl), lambda i: (0, 0)),
            pl.BlockSpec((sl, d_v), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((rb, d_v), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], d_v), q.dtype),
        interpret=True,
    )(qp, kt, v)

    return out[:sl_q]
