"""Pallas kernel for the IMC Q·K^T macro (dual-10T SRAM crossbar MAC).

Models exactly what the analog macro computes, tile-by-tile:

* Q rows arrive as 5-bit signed PWM word-line pulses (``quantize_pwm``);
* K^T is stored as 3 ganged ternary cells per weight with 1/2/4 input
  scaling — a 15-level (-7..7) grid (``quantize_ternary_cells``);
* bitline charge sharing accumulates the products down each column;
* the ramp IMA digitizes each column's MAC voltage to 5 bits
  (``adc_quantize``) over a calibrated full-scale range.

The grid tiles the output [m, n] into (row_block × crossbar_cols) blocks:
**one output tile per physical crossbar**, with the contraction dimension
(d = rows of the crossbar) kept resident — SRAM rows are not split in the
paper (64×3 rows of K^T fit one 256-row array next to the 64 replica
rows). On TPU the same BlockSpec maps a crossbar tile onto a VMEM tile
(DESIGN.md §Hardware-Adaptation).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .. import quant

#: default output tile = one crossbar's worth of columns (Sec. IV-B)
DEFAULT_COL_BLOCK = 256
DEFAULT_ROW_BLOCK = 64


def _imc_qkt_kernel(q_ref, kt_ref, o_ref, *, q_scale: float, w_scale: float,
                    adc_full_scale: float, n_bits_adc: int):
    """One grid step: quantized MAC for an output tile on one crossbar."""
    q = q_ref[...]
    kt = kt_ref[...]
    qq = quant.quantize_pwm(q, scale=q_scale)
    wq = quant.quantize_ternary_cells(kt, scale=w_scale)
    # Bitline accumulation: voltage drops add along the column.
    mac = qq @ wq
    # Ramp IMA transfer function per column output.
    o_ref[...] = quant.adc_quantize(mac, adc_full_scale, n_bits=n_bits_adc)


@functools.partial(
    jax.jit,
    static_argnames=("q_scale", "w_scale", "adc_full_scale",
                     "n_bits_adc", "row_block", "col_block"))
def imc_qkt(q: jnp.ndarray, kt: jnp.ndarray, *,
            q_scale: float, w_scale: float, adc_full_scale: float,
            n_bits_adc: int = quant.N_BITS_ADC,
            row_block: int = DEFAULT_ROW_BLOCK,
            col_block: int = DEFAULT_COL_BLOCK) -> jnp.ndarray:
    """Quantized Q·K^T as computed by the SRAM IMC macro.

    ``q``: [m, d] activations; ``kt``: [d, n] crossbar weights. Scales are
    static calibration constants (the hardware's PWM LSB, weight LSB and
    ADC full-scale are fixed at deploy time, not data-dependent).
    """
    m, d = q.shape
    d2, n = kt.shape
    assert d == d2, (q.shape, kt.shape)

    rb = min(row_block, m)
    cb = min(col_block, n)
    pad_m = (-m) % rb
    pad_n = (-n) % cb
    qp = jnp.pad(q, ((0, pad_m), (0, 0))) if pad_m else q
    ktp = jnp.pad(kt, ((0, 0), (0, pad_n))) if pad_n else kt

    grid = (qp.shape[0] // rb, ktp.shape[1] // cb)
    out = pl.pallas_call(
        functools.partial(
            _imc_qkt_kernel, q_scale=q_scale, w_scale=w_scale,
            adc_full_scale=adc_full_scale, n_bits_adc=n_bits_adc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((rb, d), lambda i, j: (i, 0)),
            pl.BlockSpec((d, cb), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((rb, cb), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((qp.shape[0], ktp.shape[1]), q.dtype),
        interpret=True,
    )(qp, ktp)

    return out[:m, :n]


def calibrate(q_sample: jnp.ndarray, kt_sample: jnp.ndarray) -> dict:
    """Derive the static hardware calibration constants from sample data.

    Mirrors the macro's one-time calibration (replica-cell ramp setting in
    [6]): PWM scale from the activation range, weight LSB from the weight
    range, ADC full-scale from the resulting MAC range.
    """
    q_scale = float(quant.symmetric_scale(q_sample, quant.N_BITS_INPUT))
    w_scale = float(quant.symmetric_scale(kt_sample, quant.CELLS_PER_WEIGHT + 1))
    qq = quant.quantize_pwm(q_sample, scale=q_scale)
    wq = quant.quantize_ternary_cells(kt_sample, scale=w_scale)
    mac = qq @ wq
    full = float(jnp.maximum(jnp.max(jnp.abs(mac)), 1e-8))
    return {"q_scale": q_scale, "w_scale": w_scale, "adc_full_scale": full}
