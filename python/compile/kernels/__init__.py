"""L1 Pallas kernels for Topkima-Former (build-time only, interpret=True).

* ``topk_softmax`` — the topkima macro's numerical contract (decreasing
  ramp + arbiter top-k selection → softmax over k values, zeros elsewhere),
  plus the per-crossbar ``sub_topk_softmax`` variant.
* ``imc_qkt`` — the dual-10T SRAM crossbar MAC with PWM inputs, ternary
  cell weights and the ramp-ADC transfer function.
* ``topkima_attention`` — the fused scale-free head: QK^T → topk softmax
  → AV, optionally with the full IMC quantization chain.
* ``ref`` — pure-jnp oracles for all of the above.
"""

from .attention import topkima_attention
from .imc_qkt import calibrate, imc_qkt
from .topk_softmax import crossbar_split, sub_topk_softmax, topk_softmax

__all__ = [
    "topkima_attention",
    "imc_qkt",
    "calibrate",
    "topk_softmax",
    "sub_topk_softmax",
    "crossbar_split",
]
