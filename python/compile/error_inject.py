"""ADC error-injection pipeline (Fig 4b).

The paper measures the distribution of the IMA circuit output against the
ideal SW MAC value over 256 conversions (SPICE), then injects that error
distribution into the SW simulation of the SRAM-mapped operations
(``Q·K^T`` and ``A·V``), observing an accuracy drop 86.7% → 85.1%.

Here the "circuit" is the rust IMA simulator; its noise model (thermal
bitline noise + SA offset + ramp INL, ``rust/src/ima/noise.rs``) is
mirrored by :func:`ima_error_model` so the python accuracy pipeline and
the rust distribution bench draw from the same family. The error is
expressed in ADC LSBs, which makes it transferable between the SPICE-level
volts of the paper and our normalized simulator.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import model as M
from . import quant


@dataclasses.dataclass(frozen=True)
class ErrorModel:
    """IMA conversion error, in units of ADC LSBs.

    * ``sigma_noise`` — random per-conversion noise (bitline thermal +
      comparator); the paper's measured spread is ~0.5 LSB.
    * ``sigma_offset`` — static per-column offset (SA mismatch), fixed per
      deployed array; calibration with replica cells cancels most of it.
    * ``p_skip`` — probability a ramp crossing is latched one cycle late
      (arbiter contention), adding exactly +1 LSB when it fires.
    """

    sigma_noise: float = 0.5
    sigma_offset: float = 0.3
    p_skip: float = 0.02


def ima_error_model(key, shape, em: ErrorModel, lsb: float,
                    column_axis: int = -1) -> jnp.ndarray:
    """Sample additive IMA error for a tensor of MAC results."""
    k1, k2, k3 = jax.random.split(key, 3)
    noise = em.sigma_noise * jax.random.normal(k1, shape)
    # static column offset: one draw per column, broadcast over rows
    col_shape = [1] * len(shape)
    col_shape[column_axis] = shape[column_axis]
    offset = em.sigma_offset * jax.random.normal(k2, tuple(col_shape))
    skip = (jax.random.uniform(k3, shape) < em.p_skip).astype(jnp.float32)
    return (noise + offset + skip) * lsb


def attention_with_ima_error(params, cfg: M.ModelConfig, inputs,
                             key, em: ErrorModel):
    """Model forward with IMA error injected on the SRAM-mapped MACs.

    Mirrors ``model._attention`` but perturbs the Q·K^T logits and the
    A·V output with the conversion-error model — the two operations the
    paper maps to (error-prone) SRAM IMC. The RRAM projections X·W are
    left exact, as in the paper's Fig 4b experiment.
    """
    def attn(x, p, key):
        b, sl, d = x.shape
        h, dh = cfg.n_heads, cfg.d_head
        q = M._dense(x, p["wq"]) / jnp.sqrt(jnp.asarray(dh, x.dtype))
        kk = M._dense(x, p["wk"])
        v = M._dense(x, p["wv"])
        q = q.reshape(b, sl, h, dh).transpose(0, 2, 1, 3)
        kk = kk.reshape(b, sl, h, dh).transpose(0, 2, 1, 3)
        v = v.reshape(b, sl, h, dh).transpose(0, 2, 1, 3)

        logits = q @ kk.transpose(0, 1, 3, 2)
        k1, k2 = jax.random.split(key)
        lsb_qkt = jnp.max(jnp.abs(logits)) / (2 ** (quant.N_BITS_ADC - 1) - 1)
        logits = logits + ima_error_model(k1, logits.shape, em, lsb_qkt)

        segments, ks = cfg.sub_topk()
        a = M.tfcbp_softmax(logits, cfg.topk, segments, ks)
        out = a @ v
        lsb_av = jnp.max(jnp.abs(out)) / (2 ** (quant.N_BITS_ADC - 1) - 1)
        out = out + ima_error_model(k2, out.shape, em, lsb_av)
        out = out.transpose(0, 2, 1, 3).reshape(b, sl, d)
        return M._dense(out, p["wo"])

    if cfg.kind == "vit":
        x = M._dense(M._patchify(inputs, cfg.patch_size), params["patch"])
        cls = jnp.broadcast_to(params["cls"], (x.shape[0], 1, cfg.d_model))
        x = jnp.concatenate([cls, x], axis=1) + params["pos"]
    else:
        x = params["tok_emb"][inputs] + params["pos"]

    for i, p in enumerate(params["layers"]):
        key, sub = jax.random.split(key)
        x = x + attn(M._layer_norm(x, p["ln1"]), p, sub)
        hcat = M._dense(M._layer_norm(x, p["ln2"]), p["ff1"])
        x = x + M._dense(jax.nn.gelu(hcat), p["ff2"])
    x = M._layer_norm(x, params["ln_f"])

    if cfg.kind == "vit":
        return M._dense(x[:, 0], params["head"])
    return M._dense(x, params["span"])


def eval_with_error(params, cfg: M.ModelConfig, eval_set, em: ErrorModel,
                    seed: int = 0, batch_size: int = 128) -> float:
    """Eval-set accuracy with IMA error injection (Fig 4b right)."""
    xs, ys = eval_set
    key = jax.random.PRNGKey(seed)
    correct, n = 0.0, 0
    for i in range(0, len(xs), batch_size):
        xb = jnp.asarray(xs[i:i + batch_size])
        yb = jnp.asarray(ys[i:i + batch_size])
        key, sub = jax.random.split(key)
        logits = attention_with_ima_error(params, cfg, xb, sub, em)
        if cfg.kind == "vit":
            correct += float(jnp.sum(jnp.argmax(logits, -1) == yb))
        else:
            ps = jnp.argmax(logits[:, :, 0], -1)
            pe = jnp.argmax(logits[:, :, 1], -1)
            correct += float(jnp.sum((ps == yb[:, 0]) & (pe == yb[:, 1])))
        n += len(xb)
    return correct / max(n, 1)
